//! Linear-product stage: the (partial) sampled gram block.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::dense::Mat;
use crate::sparse::Csr;

/// What a product stage writes into the output block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockKind {
    /// Linear inner products `⟨a_{S_r}, a_i⟩` — the engine must run the
    /// nonlinear epilogue after the reduction.
    Linear,
    /// Finished kernel values — no epilogue (Nyström factors and the
    /// PJRT artifacts already apply the kernel map).
    Kernel,
}

/// Cost record a product stage returns for the ledger.
#[derive(Clone, Copy, Debug)]
pub struct ProductCost {
    /// Flop-equivalents spent in the product.
    pub flops: f64,
    /// Rows to charge to the kernel-call counter (PJRT pads the sampled
    /// block up to the lowered artifact size, so this can exceed
    /// `sample.len()`).
    pub rows_charged: usize,
}

/// A backend that fills `q` (`sample.len() × m`) with the (partial)
/// sampled block for `sample`. Implementations must compute every output
/// row independently of the other rows in the call — that row-wise
/// independence is what makes the engine's row cache bitwise-transparent
/// (see the module docs).
pub trait ProductStage {
    /// Kernel-matrix dimension `m`.
    fn m(&self) -> usize;

    /// Whether the output needs the nonlinear epilogue.
    fn kind(&self) -> BlockKind;

    /// Fill `q` with the block for `sample`; return the ledger cost.
    fn compute(&mut self, sample: &[usize], q: &mut Mat) -> ProductCost;

    /// Apply the nonlinear epilogue to the assembled `rows × m` block.
    /// Serial by default; [`crate::parallel::ParallelProduct`] overrides
    /// this to spread the pointwise kernel map over the same worker
    /// split as the product — the epilogue is the residual serial stage
    /// once the reduce is overlapped. The map is per-element, so any
    /// row split is bitwise identical to the serial pass.
    fn apply_epilogue(&mut self, epilogue: &super::epilogue::Epilogue, rows: &[usize], q: &mut Mat) {
        epilogue.apply(rows, q);
    }

    /// Optional per-sampled-row work estimates for the threaded split:
    /// `Some(w)` (one weight per `sample` entry, arbitrary relative
    /// units) lets [`crate::parallel::ParallelProduct`] place its
    /// contiguous range boundaries by accumulated weight
    /// (`partition_by_weight`) instead of row count, which balances
    /// skewed sparse matrices. Purely a *layout* hint: every row is
    /// still computed exactly once with the serial arithmetic, so the
    /// assembled block is bitwise independent of the weights. An
    /// implementation must return a pure function of the stage's
    /// matrix and `sample` — never of threads, cache state, or timing
    /// (the bitwise-determinism contract covers layout decisions too).
    /// Default `None`: row-count-balanced ranges.
    fn sample_cost(&self, _sample: &[usize]) -> Option<Vec<u64>> {
        None
    }
}

/// Density below which the transpose-based gram beats the blocked
/// scatter-dot variant (cost `f²mn` vs `fmn` per sampled row; crossover
/// well below 1.0, with slack for its worse write locality). See §Perf in
/// EXPERIMENTS.md for the measured before/after.
pub const TRANSPOSE_GRAM_MAX_DENSITY: f64 = 0.25;

/// CSR-backed linear product: the native path for both the full matrix
/// and a 1D-column shard. Picks the transpose path for sparse data and
/// the blocked scatter-dot path otherwise, per
/// [`TRANSPOSE_GRAM_MAX_DENSITY`]. `Clone` replicates the stage per
/// worker for [`crate::parallel::ParallelProduct`] — the matrix and its
/// cached transpose are `Arc`-shared (read-only on the compute path), so
/// a clone costs two refcounts plus an empty scratch, not a copy of the
/// data.
#[derive(Clone)]
pub struct CsrProduct {
    a: Arc<Csr>,
    /// Cached transpose for the sparse fast path (None for dense data).
    at: Option<Arc<Csr>>,
    /// Dense gathered-sample-rows scratch for the blocked path (private
    /// per clone — the only `&mut` state).
    scratch: Vec<f64>,
}

impl CsrProduct {
    /// Wrap a CSR matrix (full or 1D shard), picking the compute path by
    /// its density.
    pub fn new(a: Csr) -> CsrProduct {
        let at = (a.density() < TRANSPOSE_GRAM_MAX_DENSITY).then(|| Arc::new(a.transpose()));
        Self::with_transpose(Arc::new(a), at)
    }

    /// Wrap a matrix with a caller-built transpose — the construction
    /// path for oracles that build `at` on a worker pool
    /// ([`crate::parallel::transpose_with_pool`]) before the stage
    /// exists. `at` must equal `a.transpose()` when `Some` (shape and
    /// nnz are asserted; the bitwise contract requires value equality
    /// too), and must be `Some` exactly when `a.density()` is below
    /// [`TRANSPOSE_GRAM_MAX_DENSITY`] to reproduce [`Self::new`]'s
    /// path decision.
    pub fn with_transpose(a: Arc<Csr>, at: Option<Arc<Csr>>) -> CsrProduct {
        if let Some(at) = &at {
            assert_eq!(at.nrows(), a.ncols(), "transpose row count");
            assert_eq!(at.ncols(), a.nrows(), "transpose column count");
            assert_eq!(at.nnz(), a.nnz(), "transpose nnz");
        }
        CsrProduct {
            a,
            at,
            scratch: Vec::new(),
        }
    }

    /// The underlying matrix (shard or full).
    pub fn matrix(&self) -> &Csr {
        &self.a
    }
}

impl ProductStage for CsrProduct {
    fn m(&self) -> usize {
        self.a.nrows()
    }

    fn kind(&self) -> BlockKind {
        BlockKind::Linear
    }

    fn compute(&mut self, sample: &[usize], q: &mut Mat) -> ProductCost {
        match &self.at {
            Some(at) => self.a.sampled_gram_t(at.as_ref(), sample, q),
            None => self.a.sampled_gram_blocked(sample, q, &mut self.scratch),
        }
        ProductCost {
            flops: 2.0 * sample.len() as f64 * self.a.nnz() as f64,
            rows_charged: sample.len(),
        }
    }

    /// nnz-balanced weights for the transpose path: sampled row `i`
    /// costs one column walk per stored entry, `Σ_j nnz(Aᵀ row j)` over
    /// its columns `j` — a pure function of the matrix and the sample.
    /// The blocked scatter-dot path streams all of `A` per sampled row
    /// (uniform cost), so it keeps the row-count split.
    fn sample_cost(&self, sample: &[usize]) -> Option<Vec<u64>> {
        let at = self.at.as_deref()?;
        Some(row_walk_weights(&self.a, sample, at))
    }
}

/// Per-sampled-row column-walk cost of the transpose-based gram: for
/// each sampled row of `rows`, one unit per visit of a transpose row —
/// `1 + Σ_{j ∈ cols(i)} at.row_nnz(j)` (the `1` keeps empty rows from
/// collapsing the weight vector to all zeros).
fn row_walk_weights(rows: &Csr, sample: &[usize], at: &Csr) -> Vec<u64> {
    sample
        .iter()
        .map(|&i| {
            let (cols, _) = rows.row_parts(i);
            1 + cols.iter().map(|&j| at.row_nnz(j) as u64).sum::<u64>()
        })
        .collect()
}

/// Low-rank (Nyström) product: `K̂(S, ·) = (C W⁻¹)[S, :] · Cᵀ`, a
/// `(k×l)·(l×m)` multiply over precomputed factors. Emits finished kernel
/// values ([`BlockKind::Kernel`]). The factors are `Arc`-shared, so
/// per-worker clones are free.
#[derive(Clone)]
pub struct LowRankProduct {
    /// `C W⁻¹` (m×l).
    cw: Arc<Mat>,
    /// `Cᵀ` stored row-major as l×m for contiguous row access.
    ct: Arc<Mat>,
    l: usize,
}

impl LowRankProduct {
    /// Pair the precomputed factors `C W⁻¹` (m×l) and `Cᵀ` (l×m).
    pub fn new(cw: Mat, ct: Mat) -> LowRankProduct {
        assert_eq!(cw.ncols(), ct.nrows(), "factor ranks disagree");
        assert_eq!(cw.nrows(), ct.ncols(), "factor dims disagree");
        let l = cw.ncols();
        LowRankProduct {
            cw: Arc::new(cw),
            ct: Arc::new(ct),
            l,
        }
    }

    /// Approximation rank `l`.
    pub fn rank(&self) -> usize {
        self.l
    }
}

impl ProductStage for LowRankProduct {
    fn m(&self) -> usize {
        self.cw.nrows()
    }

    fn kind(&self) -> BlockKind {
        BlockKind::Kernel
    }

    fn compute(&mut self, sample: &[usize], q: &mut Mat) -> ProductCost {
        for (r, &i) in sample.iter().enumerate() {
            let coeffs = self.cw.row(i);
            let out = q.row_mut(r);
            out.fill(0.0);
            for (t, &c) in coeffs.iter().enumerate() {
                if c == 0.0 {
                    continue;
                }
                crate::dense::axpy(c, self.ct.row(t), out);
            }
        }
        ProductCost {
            flops: 2.0 * sample.len() as f64 * self.l as f64 * self.cw.nrows() as f64,
            rows_charged: sample.len(),
        }
    }
}

/// The per-call rendezvous between the sharded grid layout's fragment
/// exchange and its product stage ([`crate::gram::GridStorage::Sharded`]):
/// `GridReduce::exchange` assembles the sampled rows' fragments from the
/// row subcommunicator and [`Self::fill`]s them here; the sharded
/// [`GridProduct`] then reads them in place of the full shard it no
/// longer stores. Shared by `Arc` between the reduce stage (one writer,
/// before the product runs) and every [`crate::parallel::ParallelProduct`]
/// worker replica (concurrent readers) — the `RwLock` is uncontended on
/// the hot path and carries no ordering decisions, so determinism is
/// untouched.
pub struct FragmentSlot {
    inner: RwLock<Assembled>,
}

/// The assembled sampled rows of one gram call: a CSR of the
/// deduplicated rows (fragment order) plus the global-row → CSR-row map.
struct Assembled {
    rows: Csr,
    pos: HashMap<usize, usize>,
}

impl FragmentSlot {
    /// An empty slot for shard width `ncols` (filled per gram call).
    pub fn new(ncols: usize) -> FragmentSlot {
        FragmentSlot {
            inner: RwLock::new(Assembled {
                rows: Csr::empty(0, ncols),
                pos: HashMap::new(),
            }),
        }
    }

    /// Install this call's assembled rows: `rows[pos[t]]` is global
    /// sampled row `t`'s fragment (a verbatim copy of the stored row).
    pub fn fill(&self, rows: Csr, pos: HashMap<usize, usize>) {
        let mut inner = self.inner.write().expect("fragment slot poisoned");
        inner.rows = rows;
        inner.pos = pos;
    }

    /// Per-sampled-row weights for the threaded split (see
    /// [`ProductStage::sample_cost`]): the fragment rows' column-walk
    /// cost against `at`. `None` when any sampled row has not been
    /// exchanged yet — a layout hint must degrade to the row-count
    /// split rather than panic (only `gather`, on the compute path
    /// proper, treats a missing fragment as a bug).
    fn weigh(&self, sample: &[usize], at: &Csr) -> Option<Vec<u64>> {
        let inner = self.inner.read().expect("fragment slot poisoned");
        sample
            .iter()
            .map(|t| {
                let &idx = inner.pos.get(t)?;
                let (cols, _) = inner.rows.row_parts(idx);
                Some(1 + cols.iter().map(|&j| at.row_nnz(j) as u64).sum::<u64>())
            })
            .collect()
    }

    /// Gather the fragments of `sample` (global row ids, duplicates
    /// allowed) in sample order. Panics if the exchange for this call
    /// has not run — the engine always exchanges before the product.
    fn gather(&self, sample: &[usize]) -> Csr {
        let inner = self.inner.read().expect("fragment slot poisoned");
        let idxs: Vec<usize> = sample
            .iter()
            .map(|t| {
                *inner.pos.get(t).unwrap_or_else(|| {
                    panic!("sampled row {t} missing from the fragment exchange")
                })
            })
            .collect();
        inner.rows.gather_rows(&idxs)
    }
}

/// Where a grid cell's product reads the *sampled* rows from.
#[derive(Clone)]
enum SampleSource {
    /// Replicated storage: the full-row feature shard (`m × ≈n/pc`).
    Replicated(Arc<Csr>),
    /// Sharded storage: the per-call fragment slot, plus the sample
    /// count `m` the dropped full shard would have reported.
    Sharded {
        slot: Arc<FragmentSlot>,
        m: usize,
    },
}

/// Grid-cell product: the partial sampled gram of one `pr × pc` grid
/// cell ([`crate::gram::Layout::Grid`]). Holds the row subset its row
/// group owns block-cyclically and computes, per sampled row, the
/// partial inner products against *owned target rows only* —
/// `1/(pr·pc)` of the global flops, versus the 1D product's `1/P` over
/// the full output width. The *sampled* side comes from one of two
/// storage modes ([`crate::gram::GridStorage`]): the replicated full-row
/// shard (`m × ≈n/pc`, gathered locally), or — the true 2D data
/// partition — the per-call [`FragmentSlot`] the fragment exchange
/// fills, in which case the cell stores only its `≈m/pr × ≈n/pc` block.
///
/// **Packed-prefix contract** (shared with `GridReduce`, its mandatory
/// pipeline partner): `compute` writes the `w = |owned|` partial values
/// of sampled row `r` into the *first `w` entries* of output row `r`,
/// leaving the remainder untouched. The reduce stage packs those
/// prefixes, sums them over the column subcommunicator, allgathers the
/// row groups' slices, and overwrites the full `k×m` block — so the
/// prefix staging is never observable outside the engine. Keeping the
/// packing row-local (rather than block-contiguous) is what lets
/// [`crate::parallel::ParallelProduct`] split sampled rows across worker
/// threads unchanged.
///
/// Bitwise contract: the path choice (transpose vs blocked scatter)
/// follows the *full shard's* density — the same decision the 1D
/// [`CsrProduct`] makes on this shard — and the target-restricted
/// kernels ([`Csr::sampled_gram_blocked_against`],
/// [`Csr::sampled_gram_t_against`]) reorder no additions, so every
/// partial entry is bitwise identical to the corresponding entry of the
/// 1D partial block on the same shard. `Clone` is cheap (`Arc`-shared
/// matrices), as [`crate::parallel::ParallelProduct`] requires.
#[derive(Clone)]
pub struct GridProduct {
    /// Where the sampled rows come from (sample indices stay global in
    /// both modes).
    source: SampleSource,
    /// The owned target rows of the shard (`|owned| × ≈n/pc`).
    owned: Arc<Csr>,
    /// Cached transpose of `owned` for the sparse fast path (None for
    /// dense shards), mirroring [`CsrProduct`]'s density decision.
    owned_t: Option<Arc<Csr>>,
    /// Dense gathered-sample scratch for the blocked path (private per
    /// clone).
    scratch: Vec<f64>,
    /// `k × |owned|` staging block (private per clone).
    block: Mat,
    /// `0..k` identity sample for the fragment-CSR kernels (private per
    /// clone, reused across calls).
    ident: Vec<usize>,
}

/// Owned target rows must be strictly ascending ([`crate::gram::block_cyclic_rows`]
/// order): the grid reduce reassembles slices by that order, so a
/// malformed row group would silently scatter reduced values to the
/// wrong sample columns. A real assert, not a `debug_assert` — one pass
/// over the row list at construction is free, and release builds must
/// fail loudly too (mirrors the `add_into` length check).
fn assert_owned_ascending(owned_rows: &[usize]) {
    assert!(
        owned_rows.windows(2).all(|w| w[0] < w[1]),
        "grid row group must be strictly ascending (got a repeated or \
         out-of-order global row index)"
    );
}

impl GridProduct {
    /// Build a replicated-storage cell from its full feature shard and
    /// the ascending global row indices its row group owns (see
    /// [`crate::gram::block_cyclic_rows`]).
    pub fn new(shard: Csr, owned_rows: &[usize]) -> GridProduct {
        assert_owned_ascending(owned_rows);
        let owned = Arc::new(shard.gather_rows(owned_rows));
        // Path choice by the FULL shard's density — identical to the 1D
        // CsrProduct on this shard, so grid partials replay its bits.
        let owned_t = (shard.density() < TRANSPOSE_GRAM_MAX_DENSITY)
            .then(|| Arc::new(owned.transpose()));
        Self::replicated_from_parts(Arc::new(shard), owned, owned_t)
    }

    /// Replicated-storage cell from pre-gathered parts — the
    /// construction path for oracles that build the transpose on a
    /// worker pool ([`crate::parallel::transpose_with_pool`]). `owned`
    /// must be `shard.gather_rows(owned_rows)` for a strictly
    /// ascending row group, and `owned_t` its transpose exactly when
    /// the *full shard's* density is below
    /// [`TRANSPOSE_GRAM_MAX_DENSITY`] — the same decisions
    /// [`Self::new`] makes, which the bitwise contract requires.
    pub fn replicated_from_parts(
        shard: Arc<Csr>,
        owned: Arc<Csr>,
        owned_t: Option<Arc<Csr>>,
    ) -> GridProduct {
        if let Some(at) = &owned_t {
            assert_eq!(at.nrows(), owned.ncols(), "owned transpose row count");
            assert_eq!(at.ncols(), owned.nrows(), "owned transpose column count");
            assert_eq!(at.nnz(), owned.nnz(), "owned transpose nnz");
        }
        GridProduct {
            source: SampleSource::Replicated(shard),
            owned,
            owned_t,
            scratch: Vec::new(),
            block: Mat::zeros(0, 0),
            ident: Vec::new(),
        }
    }

    /// Build a sharded-storage cell: only the owned row group is stored
    /// (`owned`, the `shard.gather_rows(owned_rows)` of the full shard
    /// this cell never keeps); sampled rows are read from `slot`, which
    /// `GridReduce::exchange` fills each call. `full_density` is the
    /// full shard's density — the same path decision the replicated
    /// (and 1D) product makes, reproducible from the exchanged nnz
    /// table — and `m` the global sample count.
    pub fn sharded(
        owned: Arc<Csr>,
        full_density: f64,
        m: usize,
        slot: Arc<FragmentSlot>,
    ) -> GridProduct {
        let owned_t = (full_density < TRANSPOSE_GRAM_MAX_DENSITY)
            .then(|| Arc::new(owned.transpose()));
        Self::sharded_from_parts(owned, owned_t, m, slot)
    }

    /// Sharded-storage cell with a caller-built transpose of the owned
    /// row group (see [`Self::sharded`] for the field meanings, and
    /// [`Self::replicated_from_parts`] for why oracles pass the
    /// transpose in: it is built on the product's own worker pool).
    /// `owned_t` must be `owned.transpose()` exactly when the full
    /// shard's density is below [`TRANSPOSE_GRAM_MAX_DENSITY`].
    pub fn sharded_from_parts(
        owned: Arc<Csr>,
        owned_t: Option<Arc<Csr>>,
        m: usize,
        slot: Arc<FragmentSlot>,
    ) -> GridProduct {
        if let Some(at) = &owned_t {
            assert_eq!(at.nrows(), owned.ncols(), "owned transpose row count");
            assert_eq!(at.ncols(), owned.nrows(), "owned transpose column count");
            assert_eq!(at.nnz(), owned.nnz(), "owned transpose nnz");
        }
        GridProduct {
            source: SampleSource::Sharded { slot, m },
            owned,
            owned_t,
            scratch: Vec::new(),
            block: Mat::zeros(0, 0),
            ident: Vec::new(),
        }
    }

    /// Number of target rows this cell owns.
    pub fn owned_len(&self) -> usize {
        self.owned.nrows()
    }

    /// Stored entries of the owned row group (the sharded cell's entire
    /// data residency).
    pub fn owned_nnz(&self) -> usize {
        self.owned.nnz()
    }

    /// The full-row feature shard (replicated storage only — a sharded
    /// cell stores just its owned row group, which is the point).
    pub fn shard(&self) -> Option<&Csr> {
        match &self.source {
            SampleSource::Replicated(shard) => Some(shard),
            SampleSource::Sharded { .. } => None,
        }
    }

    /// Resident stored entries of this cell's sample source: the full
    /// shard's nnz (replicated) or zero (sharded — the owned rows are
    /// counted by the caller, and the per-call assembled fragments are
    /// transient scratch).
    pub fn resident_source_nnz(&self) -> usize {
        match &self.source {
            SampleSource::Replicated(shard) => shard.nnz(),
            SampleSource::Sharded { .. } => 0,
        }
    }
}

impl ProductStage for GridProduct {
    fn m(&self) -> usize {
        match &self.source {
            SampleSource::Replicated(shard) => shard.nrows(),
            SampleSource::Sharded { m, .. } => *m,
        }
    }

    fn kind(&self) -> BlockKind {
        BlockKind::Linear
    }

    /// Per-sampled-row flop weights for the transpose fast path: the
    /// column walk over the owned transpose that `compute` performs for
    /// that row. Pure in (matrices, sample) — the dense/blocked path
    /// (and a sharded cell before its first exchange) reports `None`,
    /// falling back to row-count splits.
    fn sample_cost(&self, sample: &[usize]) -> Option<Vec<u64>> {
        let at = self.owned_t.as_deref()?;
        match &self.source {
            SampleSource::Replicated(shard) => Some(row_walk_weights(shard, sample, at)),
            SampleSource::Sharded { slot, .. } => slot.weigh(sample, at),
        }
    }

    fn compute(&mut self, sample: &[usize], q: &mut Mat) -> ProductCost {
        let k = sample.len();
        let w = self.owned.nrows();
        debug_assert_eq!(q.nrows(), k);
        debug_assert_eq!(q.ncols(), self.m());
        if self.block.nrows() != k || self.block.ncols() != w {
            self.block = Mat::zeros(k, w);
        }
        match &self.source {
            SampleSource::Replicated(shard) => match &self.owned_t {
                Some(at) => shard.sampled_gram_t_against(at, sample, &mut self.block),
                None => shard.sampled_gram_blocked_against(
                    sample,
                    &self.owned,
                    &mut self.block,
                    &mut self.scratch,
                ),
            },
            SampleSource::Sharded { slot, .. } => {
                // The assembled fragments are verbatim copies of the
                // full shard's rows, gathered into sample order — so
                // running the identity-sample kernels over them replays
                // exactly the bits of the replicated gather-from-shard
                // path (same values, same stored order, same adds).
                let gathered = slot.gather(sample);
                self.ident.clear();
                self.ident.extend(0..k);
                match &self.owned_t {
                    Some(at) => {
                        gathered.sampled_gram_t_against(at, &self.ident, &mut self.block)
                    }
                    None => gathered.sampled_gram_blocked_against(
                        &self.ident,
                        &self.owned,
                        &mut self.block,
                        &mut self.scratch,
                    ),
                }
            }
        }
        for r in 0..k {
            q.row_mut(r)[..w].copy_from_slice(self.block.row(r));
        }
        ProductCost {
            flops: 2.0 * k as f64 * self.owned.nnz() as f64,
            rows_charged: k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg;

    #[test]
    fn csr_product_selects_path_by_density_and_paths_agree() {
        let mut r = Pcg::seeded(31);
        for density in [0.02, 0.9] {
            let mut trips = Vec::new();
            for i in 0..20 {
                for j in 0..30 {
                    if r.next_f64() < density {
                        trips.push((i, j, r.next_gaussian()));
                    }
                }
            }
            let a = Csr::from_triplets(20, 30, &trips);
            let sparse_path = a.density() < TRANSPOSE_GRAM_MAX_DENSITY;
            let mut p = CsrProduct::new(a.clone());
            assert_eq!(p.at.is_some(), sparse_path, "density {density}");
            assert_eq!(p.kind(), BlockKind::Linear);
            let sample = vec![3usize, 11, 3];
            let mut q = Mat::zeros(3, 20);
            let cost = p.compute(&sample, &mut q);
            assert_eq!(cost.rows_charged, 3);
            assert_eq!(cost.flops, 2.0 * 3.0 * a.nnz() as f64);
            // Reference: the scatter variant.
            let mut q_ref = Mat::zeros(3, 20);
            let mut scratch = Vec::new();
            a.sampled_gram(&sample, &mut q_ref, &mut scratch);
            for (x, y) in q.data().iter().zip(q_ref.data()) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    /// The grid product's packed prefix must be a bitwise column slice of
    /// the 1D product's block on the same shard, on both density paths,
    /// and its flop charge must be the owned share of the 1D charge.
    #[test]
    fn grid_product_prefix_is_bitwise_slice_of_csr_product() {
        let mut r = Pcg::seeded(37);
        for density in [0.03, 0.8] {
            let mut trips = Vec::new();
            for i in 0..18 {
                for j in 0..24 {
                    if r.next_f64() < density {
                        trips.push((i, j, r.next_gaussian()));
                    }
                }
            }
            let a = Csr::from_triplets(18, 24, &trips);
            let owned: Vec<usize> = crate::gram::block_cyclic_rows(18, 3, 1, 2);
            let mut full = CsrProduct::new(a.clone());
            let mut grid = GridProduct::new(a.clone(), &owned);
            assert_eq!(grid.m(), 18);
            assert_eq!(grid.kind(), BlockKind::Linear);
            assert_eq!(grid.owned_len(), owned.len());
            let sample = vec![5usize, 11, 5, 2];
            let mut q_full = Mat::zeros(4, 18);
            full.compute(&sample, &mut q_full);
            let mut q_grid = Mat::zeros(4, 18);
            let cost = grid.compute(&sample, &mut q_grid);
            for rr in 0..sample.len() {
                for (u, &t) in owned.iter().enumerate() {
                    assert_eq!(
                        q_grid[(rr, u)],
                        q_full[(rr, t)],
                        "density {density} ({rr},{t})"
                    );
                }
            }
            let owned_nnz: usize = owned.iter().map(|&t| a.row_nnz(t)).sum();
            assert_eq!(cost.flops, 2.0 * 4.0 * owned_nnz as f64);
            assert_eq!(cost.rows_charged, 4);
        }
    }

    /// A sharded cell fed assembled fragments through the slot must
    /// replay the replicated cell's bits on both density paths,
    /// duplicates included, and report zero resident source nnz.
    #[test]
    fn sharded_grid_product_is_bitwise_equal_to_replicated() {
        let mut r = Pcg::seeded(41);
        for density in [0.03, 0.8] {
            let mut trips = Vec::new();
            for i in 0..18 {
                for j in 0..24 {
                    if r.next_f64() < density {
                        trips.push((i, j, r.next_gaussian()));
                    }
                }
            }
            let a = Csr::from_triplets(18, 24, &trips);
            let owned_rows: Vec<usize> = crate::gram::block_cyclic_rows(18, 3, 1, 2);
            let mut replicated = GridProduct::new(a.clone(), &owned_rows);
            let owned = std::sync::Arc::new(a.gather_rows(&owned_rows));
            let slot = std::sync::Arc::new(FragmentSlot::new(24));
            let mut sharded =
                GridProduct::sharded(owned, a.density(), 18, slot.clone());
            assert_eq!(sharded.m(), 18);
            assert_eq!(sharded.owned_len(), owned_rows.len());
            assert!(sharded.shard().is_none());
            assert_eq!(sharded.resident_source_nnz(), 0);
            assert_eq!(replicated.resident_source_nnz(), a.nnz());

            let sample = vec![5usize, 11, 5, 2];
            // Assemble the fragments the exchange would deliver: the
            // deduplicated sampled rows, verbatim, in any order + map.
            let uniq = vec![2usize, 5, 11];
            let rows = a.gather_rows(&uniq);
            let pos: HashMap<usize, usize> =
                uniq.iter().enumerate().map(|(i, &t)| (t, i)).collect();
            slot.fill(rows, pos);

            let mut q_rep = Mat::zeros(4, 18);
            let cost_rep = replicated.compute(&sample, &mut q_rep);
            let mut q_sh = Mat::zeros(4, 18);
            let cost_sh = sharded.compute(&sample, &mut q_sh);
            let w = owned_rows.len();
            for rr in 0..4 {
                assert_eq!(
                    &q_sh.row(rr)[..w],
                    &q_rep.row(rr)[..w],
                    "density {density} row {rr}"
                );
            }
            assert_eq!(cost_sh.flops, cost_rep.flops);
            assert_eq!(cost_sh.rows_charged, cost_rep.rows_charged);
        }
    }

    /// The PR 2-style hardening satellite: malformed row groups are a
    /// hard error in release builds too, not a `debug_assert`.
    #[test]
    #[should_panic(expected = "strictly ascending")]
    fn grid_product_rejects_malformed_row_groups() {
        let a = Csr::from_triplets(4, 3, &[(0, 0, 1.0), (2, 1, 2.0)]);
        let _ = GridProduct::new(a, &[2, 1]);
    }
}
