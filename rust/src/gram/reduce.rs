//! Reduction stage: combine partial blocks across column shards.

use crate::comm::{allgatherv, allreduce_sum, AllreduceAlgo, CommStats, Communicator, SubComm};

use super::layout::block_cyclic_rows;

/// Combines the product stage's (partial) block across ranks.
pub trait ReduceStage {
    /// False for local engines — the engine then skips the reduction
    /// entirely (no phase timing, no counters).
    fn is_active(&self) -> bool;

    /// In-place sum-reduction of the flat block buffer.
    fn reduce(&mut self, buf: &mut [f64]);

    /// Traffic accumulated by this stage's communicator.
    fn stats(&self) -> CommStats;
}

/// The local no-op reduction (full-matrix layouts).
#[derive(Clone, Copy, Debug, Default)]
pub struct NoReduce;

impl ReduceStage for NoReduce {
    fn is_active(&self) -> bool {
        false
    }

    fn reduce(&mut self, _buf: &mut [f64]) {}

    fn stats(&self) -> CommStats {
        CommStats::default()
    }
}

/// Sum-allreduce over a [`Communicator`] — the per-iteration collective
/// the s-step methods amortize and the row cache skips on full hits.
pub struct AllreduceSum<'c, C: Communicator> {
    comm: &'c mut C,
    algo: AllreduceAlgo,
}

impl<'c, C: Communicator> AllreduceSum<'c, C> {
    /// Wrap a communicator with the chosen allreduce algorithm.
    pub fn new(comm: &'c mut C, algo: AllreduceAlgo) -> Self {
        AllreduceSum { comm, algo }
    }

    /// This rank's id (exposed for the oracle wrappers).
    pub fn rank(&self) -> usize {
        self.comm.rank()
    }

    /// Direct access for construction-time collectives (row norms).
    pub fn comm_mut(&mut self) -> &mut C {
        self.comm
    }
}

impl<'c, C: Communicator> ReduceStage for AllreduceSum<'c, C> {
    fn is_active(&self) -> bool {
        true
    }

    fn reduce(&mut self, buf: &mut [f64]) {
        allreduce_sum(self.comm, buf, self.algo);
    }

    fn stats(&self) -> CommStats {
        self.comm.stats()
    }
}

/// The 2D grid reduction: the matched pipeline partner of
/// `GridProduct`'s packed-prefix partial blocks
/// ([`crate::gram::Layout::Grid`]).
///
/// `reduce` runs three steps, all attributed to the engine's allreduce
/// phase:
///
/// 1. **Pack** — copy each output row's `w = |owned|` partial prefix into
///    a contiguous `k×w` buffer.
/// 2. **Column reduce** — sum the `pc` feature-shard partials with an
///    [`allreduce_sum`] over the *column subcommunicator* (the `pc` grid
///    cells of this row group): the collective the grid shrinks from `P`
///    participants moving `k·m` words to `pc` participants moving
///    `k·m/pr`.
/// 3. **Row allgather + scatter** — [`allgatherv`] the `pr` row groups'
///    reduced slices over the *row subcommunicator* (the `pr` cells
///    holding this feature shard) and scatter them back into the full
///    row-major `k×m` block via each group's block-cyclic column set.
///
/// Traffic is accounted per subcommunicator (`col_stats` / `row_stats`);
/// [`ReduceStage::stats`] reports their [`CommStats::plus`] sum, since
/// the two stages are sequential on every rank.
pub struct GridReduce<'c, C: Communicator> {
    comm: &'c mut C,
    algo: AllreduceAlgo,
    /// Kernel-matrix dimension `m` (the full block width).
    m: usize,
    /// Ascending global sample columns owned by each row group.
    owned: Vec<Vec<usize>>,
    /// This rank's row-group index `i`.
    my_group: usize,
    /// Global ranks of this rank's column subcommunicator (`pc` cells of
    /// grid row `i`, in feature-shard order — group rank `j` matches 1D
    /// rank `j`, which is what makes the reduce replay the 1D bits).
    col_members: Vec<usize>,
    /// Global ranks of this rank's row subcommunicator (`pr` cells
    /// holding feature shard `j`, in row-group order).
    row_members: Vec<usize>,
    col_stats: CommStats,
    row_stats: CommStats,
    /// Reused `k×w` packed buffer.
    packed: Vec<f64>,
}

impl<'c, C: Communicator> GridReduce<'c, C> {
    /// Carve the `pr × pc` grid's subcommunicators out of `comm` (which
    /// must span exactly `pr·pc` ranks; rank `r` is grid cell
    /// `(r / pc, r % pc)`). `m` is the sample count and `row_block` the
    /// block-cyclic block size.
    pub fn new(
        comm: &'c mut C,
        algo: AllreduceAlgo,
        pr: usize,
        pc: usize,
        m: usize,
        row_block: usize,
    ) -> Self {
        assert!(pr >= 1 && pc >= 1, "grid dimensions must be positive");
        assert_eq!(
            comm.size(),
            pr * pc,
            "a {pr}x{pc} grid needs exactly pr*pc ranks, got {}",
            comm.size()
        );
        let rank = comm.rank();
        let (i, j) = (rank / pc, rank % pc);
        GridReduce {
            comm,
            algo,
            m,
            owned: (0..pr)
                .map(|g| block_cyclic_rows(m, pr, g, row_block))
                .collect(),
            my_group: i,
            col_members: (0..pc).map(|jj| i * pc + jj).collect(),
            row_members: (0..pr).map(|ii| ii * pc + j).collect(),
            col_stats: CommStats::default(),
            row_stats: CommStats::default(),
            packed: Vec::new(),
        }
    }

    /// This rank's global id (exposed for the oracle wrappers).
    pub fn rank(&self) -> usize {
        self.comm.rank()
    }

    /// The ascending sample columns this rank's row group owns.
    pub fn owned_rows(&self) -> &[usize] {
        &self.owned[self.my_group]
    }

    /// Sum-allreduce over the column subcommunicator — used by the grid
    /// oracle for the construction-time row-norms reduction (the norms
    /// are a sum over the `pc` feature shards, exactly like the gram).
    pub fn allreduce_col(&mut self, buf: &mut [f64]) {
        let mut sub = SubComm::new(&mut *self.comm, &self.col_members, &mut self.col_stats);
        allreduce_sum(&mut sub, buf, self.algo);
    }

    /// Column-subcommunicator (reduce) traffic so far.
    pub fn col_stats(&self) -> CommStats {
        self.col_stats
    }

    /// Row-subcommunicator (allgather) traffic so far.
    pub fn row_stats(&self) -> CommStats {
        self.row_stats
    }
}

impl<'c, C: Communicator> ReduceStage for GridReduce<'c, C> {
    fn is_active(&self) -> bool {
        true
    }

    fn reduce(&mut self, buf: &mut [f64]) {
        let m = self.m;
        assert_eq!(buf.len() % m, 0, "grid reduce: buffer must be k x m");
        let k = buf.len() / m;
        let w = self.owned[self.my_group].len();
        // 1. Pack the per-row partial prefixes (GridProduct's contract).
        self.packed.clear();
        self.packed.resize(k * w, 0.0);
        for r in 0..k {
            self.packed[r * w..(r + 1) * w].copy_from_slice(&buf[r * m..r * m + w]);
        }
        // 2. Sum the pc feature-shard partials over the column subcomm.
        {
            let mut sub = SubComm::new(&mut *self.comm, &self.col_members, &mut self.col_stats);
            allreduce_sum(&mut sub, &mut self.packed, self.algo);
        }
        // 3. Allgather the pr reduced slices along the row subcomm and
        //    scatter them into the full row-major k×m block.
        let counts: Vec<usize> = self.owned.iter().map(|o| k * o.len()).collect();
        let gathered = {
            let mut sub = SubComm::new(&mut *self.comm, &self.row_members, &mut self.row_stats);
            allgatherv(&mut sub, &self.packed, &counts)
        };
        let mut off = 0usize;
        for (g, rows) in self.owned.iter().enumerate() {
            let wg = rows.len();
            for r in 0..k {
                let slice = &gathered[off + r * wg..off + (r + 1) * wg];
                for (u, &t) in rows.iter().enumerate() {
                    buf[r * m + t] = slice[u];
                }
            }
            off += counts[g];
        }
    }

    fn stats(&self) -> CommStats {
        self.col_stats.plus(self.row_stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_ranks;

    #[test]
    fn no_reduce_is_inert() {
        let mut r = NoReduce;
        let mut buf = vec![1.0, 2.0];
        r.reduce(&mut buf);
        assert!(!r.is_active());
        assert_eq!(buf, vec![1.0, 2.0]);
        assert_eq!(r.stats(), CommStats::default());
    }

    /// End-to-end grid reduce over a 2×2 grid: packed prefixes in, fully
    /// reduced and reassembled k×m blocks out, with traffic split between
    /// the column and row subcommunicators.
    #[test]
    fn grid_reduce_sums_over_columns_and_reassembles_rows() {
        let (pr, pc, m, k) = (2usize, 2usize, 5usize, 2usize);
        let outs = run_ranks(pr * pc, |c| {
            let rank = c.rank();
            let (i, j) = (rank / pc, rank % pc);
            let mut stage =
                GridReduce::new(c, AllreduceAlgo::RecursiveDoubling, pr, pc, m, 1);
            assert!(stage.is_active());
            let owned: Vec<usize> = stage.owned_rows().to_vec();
            // Fill per the GridProduct packed-prefix contract: garbage
            // beyond the prefix must be overwritten by the reduce.
            let mut buf = vec![f64::NAN; k * m];
            for r in 0..k {
                for (u, &t) in owned.iter().enumerate() {
                    buf[r * m + u] = ((j + 1) * 100 + r * 10 + t) as f64;
                }
            }
            stage.reduce(&mut buf);
            (buf, i, stage.col_stats(), stage.row_stats())
        });
        for (buf, _i, col, row) in &outs {
            for r in 0..k {
                for t in 0..m {
                    // Σ over the two feature shards of (j+1)·100 + r·10 + t.
                    let expect = 300.0 + 2.0 * (r * 10 + t) as f64;
                    assert_eq!(buf[r * m + t], expect, "({r},{t})");
                }
            }
            assert_eq!(col.allreduces, 1);
            assert!(col.words > 0 && row.words > 0);
            assert_eq!(row.allreduces, 0, "the allgather is not an allreduce");
        }
        // Row groups own {0,2,4} and {1,3}: rank 0's reduce payload is
        // k·3 words (recursive doubling over pc=2 sends it once), and the
        // two-rank allgather ring sends its own k·3-word slice once.
        let (_, _, col0, row0) = &outs[0];
        assert_eq!(col0.words, (k * 3) as u64);
        assert_eq!(row0.words, (k * 3) as u64);
    }

    #[test]
    fn allreduce_stage_sums_and_counts() {
        let outs = run_ranks(4, |c| {
            let mut stage = AllreduceSum::new(c, AllreduceAlgo::RecursiveDoubling);
            assert!(stage.is_active());
            let mut buf = vec![stage.rank() as f64 + 1.0; 8];
            stage.reduce(&mut buf);
            (buf, stage.stats())
        });
        for (buf, stats) in &outs {
            assert!(buf.iter().all(|&v| v == 10.0));
            assert_eq!(stats.allreduces, 1);
            assert_eq!(stats.words, 8 * 2); // w·log2(4)
        }
    }
}
