//! Reduction stage: combine partial blocks across column shards.

use crate::comm::{allreduce_sum, AllreduceAlgo, CommStats, Communicator};

/// Combines the product stage's (partial) block across ranks.
pub trait ReduceStage {
    /// False for local engines — the engine then skips the reduction
    /// entirely (no phase timing, no counters).
    fn is_active(&self) -> bool;

    /// In-place sum-reduction of the flat block buffer.
    fn reduce(&mut self, buf: &mut [f64]);

    /// Traffic accumulated by this stage's communicator.
    fn stats(&self) -> CommStats;
}

/// The local no-op reduction (full-matrix layouts).
#[derive(Clone, Copy, Debug, Default)]
pub struct NoReduce;

impl ReduceStage for NoReduce {
    fn is_active(&self) -> bool {
        false
    }

    fn reduce(&mut self, _buf: &mut [f64]) {}

    fn stats(&self) -> CommStats {
        CommStats::default()
    }
}

/// Sum-allreduce over a [`Communicator`] — the per-iteration collective
/// the s-step methods amortize and the row cache skips on full hits.
pub struct AllreduceSum<'c, C: Communicator> {
    comm: &'c mut C,
    algo: AllreduceAlgo,
}

impl<'c, C: Communicator> AllreduceSum<'c, C> {
    pub fn new(comm: &'c mut C, algo: AllreduceAlgo) -> Self {
        AllreduceSum { comm, algo }
    }

    /// This rank's id (exposed for the oracle wrappers).
    pub fn rank(&self) -> usize {
        self.comm.rank()
    }

    /// Direct access for construction-time collectives (row norms).
    pub fn comm_mut(&mut self) -> &mut C {
        self.comm
    }
}

impl<'c, C: Communicator> ReduceStage for AllreduceSum<'c, C> {
    fn is_active(&self) -> bool {
        true
    }

    fn reduce(&mut self, buf: &mut [f64]) {
        allreduce_sum(self.comm, buf, self.algo);
    }

    fn stats(&self) -> CommStats {
        self.comm.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_ranks;

    #[test]
    fn no_reduce_is_inert() {
        let mut r = NoReduce;
        let mut buf = vec![1.0, 2.0];
        r.reduce(&mut buf);
        assert!(!r.is_active());
        assert_eq!(buf, vec![1.0, 2.0]);
        assert_eq!(r.stats(), CommStats::default());
    }

    #[test]
    fn allreduce_stage_sums_and_counts() {
        let outs = run_ranks(4, |c| {
            let mut stage = AllreduceSum::new(c, AllreduceAlgo::RecursiveDoubling);
            assert!(stage.is_active());
            let mut buf = vec![stage.rank() as f64 + 1.0; 8];
            stage.reduce(&mut buf);
            (buf, stage.stats())
        });
        for (buf, stats) in &outs {
            assert!(buf.iter().all(|&v| v == 10.0));
            assert_eq!(stats.allreduces, 1);
            assert_eq!(stats.words, 8 * 2); // w·log2(4)
        }
    }
}
