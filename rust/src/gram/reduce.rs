//! Reduction stage: combine partial blocks across column shards.

use std::collections::HashMap;
use std::sync::Arc;

use crate::comm::{
    allgatherv, allreduce_sum, AllreduceAlgo, CollectiveHandle, CommStats, Communicator, SubComm,
};
use crate::sparse::Csr;

use super::layout::block_cyclic_rows;
use super::product::FragmentSlot;

/// Combines the product stage's (partial) block across ranks.
pub trait ReduceStage {
    /// False for local engines — the engine then skips the reduction
    /// entirely (no phase timing, no counters).
    fn is_active(&self) -> bool;

    /// In-place sum-reduction of the flat block buffer.
    fn reduce(&mut self, buf: &mut [f64]);

    /// Traffic accumulated by this stage's communicator.
    fn stats(&self) -> CommStats;

    /// Pre-product hook for layouts whose sampled-row inputs must be
    /// assembled from remote fragments ([`crate::gram::GridStorage::Sharded`]):
    /// called with the rows the product is about to compute (the
    /// engine's deduplicated miss set when the cache is on, the raw
    /// sample otherwise). No-op by default.
    fn exchange(&mut self, _rows: &[usize]) {}

    /// True when [`ReduceStage::exchange`] does real work — the engine
    /// then times it as [`crate::costmodel::Phase::FragmentExchange`].
    fn has_exchange(&self) -> bool {
        false
    }

    /// Nonblocking variant of [`ReduceStage::exchange`]
    /// ([`crate::gram::OverlapMode::Exchange`]): publish the fragments
    /// this rank can serve locally, *post* the exchange ring, and return
    /// the traffic the posted collective will account (the ledger's
    /// posted/overlappable column). The engine runs the owned-rows
    /// product pass under the in-flight ring, then calls
    /// [`ReduceStage::exchange_finish`]. Default: run the blocking
    /// exchange — nothing posted.
    fn exchange_start(&mut self, rows: &[usize]) -> CommStats {
        self.exchange(rows);
        CommStats::default()
    }

    /// Complete an exchange opened by [`ReduceStage::exchange_start`]
    /// (no-op when nothing was posted).
    fn exchange_finish(&mut self) {}

    /// For each of `rows`, whether this rank can serve the row's
    /// fragment locally while an exchange is in flight (the sampled rows
    /// its own row group stores). All-false by default — stages without
    /// an exchange have nothing to split the product over.
    fn local_mask(&self, rows: &[usize]) -> Vec<bool> {
        vec![false; rows.len()]
    }

    /// Nonblocking variant of [`ReduceStage::reduce`]
    /// ([`crate::gram::OverlapMode::Pipeline`]): *post* the reduction of
    /// `buf` and return the posted collective's traffic. The s-step
    /// driver runs the previous block's inner updates under the
    /// in-flight reduce, then calls [`ReduceStage::reduce_finish`].
    /// Default: post nothing and defer the whole reduction to the
    /// finish.
    fn reduce_start(&mut self, _buf: &[f64]) -> CommStats {
        CommStats::default()
    }

    /// Complete a reduction opened by [`ReduceStage::reduce_start`],
    /// writing the reduced block into `buf`. Default: the blocking
    /// reduce (matching the default `reduce_start`, which posts
    /// nothing).
    fn reduce_finish(&mut self, buf: &mut [f64]) {
        self.reduce(buf);
    }
}

/// The local no-op reduction (full-matrix layouts).
#[derive(Clone, Copy, Debug, Default)]
pub struct NoReduce;

impl ReduceStage for NoReduce {
    fn is_active(&self) -> bool {
        false
    }

    fn reduce(&mut self, _buf: &mut [f64]) {}

    fn stats(&self) -> CommStats {
        CommStats::default()
    }
}

/// Sum-allreduce over a [`Communicator`] — the per-iteration collective
/// the s-step methods amortize and the row cache skips on full hits.
pub struct AllreduceSum<'c, C: Communicator> {
    comm: &'c mut C,
    algo: AllreduceAlgo,
    /// In-flight posted allreduce (pipeline overlap), if any.
    pending: Option<CollectiveHandle>,
}

impl<'c, C: Communicator> AllreduceSum<'c, C> {
    /// Wrap a communicator with the chosen allreduce algorithm.
    pub fn new(comm: &'c mut C, algo: AllreduceAlgo) -> Self {
        AllreduceSum {
            comm,
            algo,
            pending: None,
        }
    }

    /// This rank's id (exposed for the oracle wrappers).
    pub fn rank(&self) -> usize {
        self.comm.rank()
    }

    /// Direct access for construction-time collectives (row norms).
    pub fn comm_mut(&mut self) -> &mut C {
        self.comm
    }
}

impl<'c, C: Communicator> ReduceStage for AllreduceSum<'c, C> {
    fn is_active(&self) -> bool {
        true
    }

    fn reduce(&mut self, buf: &mut [f64]) {
        allreduce_sum(self.comm, buf, self.algo);
    }

    fn stats(&self) -> CommStats {
        self.comm.stats()
    }

    fn reduce_start(&mut self, buf: &[f64]) -> CommStats {
        assert!(
            self.pending.is_none(),
            "reduce_start: a reduction is already in flight"
        );
        let h = CollectiveHandle::post_allreduce(self.comm, buf.to_vec(), self.algo);
        let posted = h.posted_stats();
        self.pending = Some(h);
        posted
    }

    fn reduce_finish(&mut self, buf: &mut [f64]) {
        let mut h = self
            .pending
            .take()
            .expect("reduce_finish without a matching reduce_start");
        let out = h.wait(self.comm);
        buf.copy_from_slice(&out);
    }
}

/// The 2D grid reduction: the matched pipeline partner of
/// `GridProduct`'s packed-prefix partial blocks
/// ([`crate::gram::Layout::Grid`]).
///
/// `reduce` runs three steps, all attributed to the engine's allreduce
/// phase:
///
/// 1. **Pack** — copy each output row's `w = |owned|` partial prefix into
///    a contiguous `k×w` buffer.
/// 2. **Column reduce** — sum the `pc` feature-shard partials with an
///    [`allreduce_sum`] over the *column subcommunicator* (the `pc` grid
///    cells of this row group): the collective the grid shrinks from `P`
///    participants moving `k·m` words to `pc` participants moving
///    `k·m/pr`.
/// 3. **Row allgather + scatter** — [`allgatherv`] the `pr` row groups'
///    reduced slices over the *row subcommunicator* (the `pr` cells
///    holding this feature shard) and scatter them back into the full
///    row-major `k×m` block via each group's block-cyclic column set.
///
/// Traffic is accounted per subcommunicator (`col_stats` / `row_stats`);
/// [`ReduceStage::stats`] reports their [`CommStats::plus`] sum, since
/// the two stages are sequential on every rank.
pub struct GridReduce<'c, C: Communicator> {
    comm: &'c mut C,
    algo: AllreduceAlgo,
    /// Kernel-matrix dimension `m` (the full block width).
    m: usize,
    /// Ascending global sample columns owned by each row group.
    owned: Vec<Vec<usize>>,
    /// This rank's row-group index `i`.
    my_group: usize,
    /// Global ranks of this rank's column subcommunicator (`pc` cells of
    /// grid row `i`, in feature-shard order — group rank `j` matches 1D
    /// rank `j`, which is what makes the reduce replay the 1D bits).
    col_members: Vec<usize>,
    /// Global ranks of this rank's row subcommunicator (`pr` cells
    /// holding feature shard `j`, in row-group order).
    row_members: Vec<usize>,
    /// Block-cyclic block size (the row-ownership map, shared with the
    /// fragment exchange's group partition).
    row_block: usize,
    col_stats: CommStats,
    row_stats: CommStats,
    /// Fragment-exchange (sharded storage) traffic so far.
    exch_stats: CommStats,
    /// Sharded-storage exchange state (`None` for replicated cells).
    sharded: Option<ShardedExchange>,
    /// Reused `k×w` packed buffer.
    packed: Vec<f64>,
    /// In-flight posted fragment exchange (exchange overlap), if any.
    pending_exchange: Option<PendingExchange>,
    /// In-flight posted column reduce (pipeline overlap) and its block
    /// row count `k`, if any.
    pending_reduce: Option<(CollectiveHandle, usize)>,
}

/// A fragment exchange between `exchange_start` and `exchange_finish`:
/// the posted ring plus the group-major row order and per-row nnz needed
/// to rebuild the fragments once the ring completes.
struct PendingExchange {
    handle: CollectiveHandle,
    /// Deduplicated sampled rows in group-major (gathered) order.
    order: Vec<usize>,
    /// Stored-entry count of each row of `order`, for `Csr::from_packed`.
    row_nnz: Vec<usize>,
}

/// State of the sharded-storage fragment exchange
/// ([`crate::gram::GridStorage::Sharded`]): the cell's owned-row CSR
/// (fragment source), the full shard-wide per-row nnz table gathered
/// once at setup (so per-call ring counts are known a priori on every
/// rank — `allgatherv` schedules need no size messages), and the slot
/// the assembled rows are published through.
struct ShardedExchange {
    /// This cell's owned rows (`|owned| × ≈n/pc`), ascending global order.
    owned_src: Arc<Csr>,
    /// Stored-entry count of every global row within this feature shard.
    nnz_table: Vec<usize>,
    /// Rendezvous with the sharded [`crate::gram::GridProduct`].
    slot: Arc<FragmentSlot>,
}

impl<'c, C: Communicator> GridReduce<'c, C> {
    /// Carve the `pr × pc` grid's subcommunicators out of `comm` (which
    /// must span exactly `pr·pc` ranks; rank `r` is grid cell
    /// `(r / pc, r % pc)`). `m` is the sample count and `row_block` the
    /// block-cyclic block size.
    pub fn new(
        comm: &'c mut C,
        algo: AllreduceAlgo,
        pr: usize,
        pc: usize,
        m: usize,
        row_block: usize,
    ) -> Self {
        assert!(pr >= 1 && pc >= 1, "grid dimensions must be positive");
        assert_eq!(
            comm.size(),
            pr * pc,
            "a {pr}x{pc} grid needs exactly pr*pc ranks, got {}",
            comm.size()
        );
        let rank = comm.rank();
        let (i, j) = (rank / pc, rank % pc);
        GridReduce {
            comm,
            algo,
            m,
            owned: (0..pr)
                .map(|g| block_cyclic_rows(m, pr, g, row_block))
                .collect(),
            my_group: i,
            col_members: (0..pc).map(|jj| i * pc + jj).collect(),
            row_members: (0..pr).map(|ii| ii * pc + j).collect(),
            row_block,
            col_stats: CommStats::default(),
            row_stats: CommStats::default(),
            exch_stats: CommStats::default(),
            sharded: None,
            packed: Vec::new(),
            pending_exchange: None,
            pending_reduce: None,
        }
    }

    /// This rank's global id (exposed for the oracle wrappers).
    pub fn rank(&self) -> usize {
        self.comm.rank()
    }

    /// The ascending sample columns this rank's row group owns.
    pub fn owned_rows(&self) -> &[usize] {
        &self.owned[self.my_group]
    }

    /// Sum-allreduce over the column subcommunicator — used by the grid
    /// oracle for the construction-time row-norms reduction (the norms
    /// are a sum over the `pc` feature shards, exactly like the gram).
    pub fn allreduce_col(&mut self, buf: &mut [f64]) {
        let mut sub = SubComm::new(&mut *self.comm, &self.col_members, &mut self.col_stats);
        allreduce_sum(&mut sub, buf, self.algo);
    }

    /// Column-subcommunicator (reduce) traffic so far.
    pub fn col_stats(&self) -> CommStats {
        self.col_stats
    }

    /// Row-subcommunicator (allgather) traffic so far.
    pub fn row_stats(&self) -> CommStats {
        self.row_stats
    }

    /// Fragment-exchange (sharded storage) traffic so far — zero for
    /// replicated cells.
    pub fn exch_stats(&self) -> CommStats {
        self.exch_stats
    }

    /// Switch this cell to sharded storage
    /// ([`crate::gram::GridStorage::Sharded`]): install the owned-row
    /// fragment source and the product's [`FragmentSlot`], and run the
    /// one-time **setup ring** — an `allgatherv` over the row
    /// subcommunicator of every owned row's `(‖row‖², nnz)` pair
    /// (counts `2·|owned_g|` are known a priori from the block-cyclic
    /// map). Returns the full shard-wide row-norm vector, assembled
    /// from verbatim per-row values — bitwise the `row_norms_sq()` of
    /// the full shard the cell no longer stores — ready for the same
    /// column-subcommunicator allreduce the replicated path runs. The
    /// gathered nnz table makes every later per-call exchange a single
    /// ring with locally computable counts. Collective over the row
    /// subcommunicator; traffic lands in [`Self::exch_stats`].
    pub fn enable_sharded(&mut self, owned_src: Arc<Csr>, slot: Arc<FragmentSlot>) -> Vec<f64> {
        let my_rows = &self.owned[self.my_group];
        assert_eq!(
            owned_src.nrows(),
            my_rows.len(),
            "sharded grid cell: owned CSR must hold exactly the row group"
        );
        let norms = owned_src.row_norms_sq();
        let mut mine = Vec::with_capacity(2 * my_rows.len());
        for (u, &nrm) in norms.iter().enumerate() {
            mine.push(nrm);
            mine.push(owned_src.row_nnz(u) as f64);
        }
        let counts: Vec<usize> = self.owned.iter().map(|o| 2 * o.len()).collect();
        let gathered = {
            let mut sub = SubComm::new(&mut *self.comm, &self.row_members, &mut self.exch_stats);
            allgatherv(&mut sub, &mine, &counts)
        };
        let mut full_norms = vec![0.0; self.m];
        let mut nnz_table = vec![0usize; self.m];
        let mut off = 0usize;
        for (g, rows) in self.owned.iter().enumerate() {
            for (u, &t) in rows.iter().enumerate() {
                full_norms[t] = gathered[off + 2 * u];
                nnz_table[t] = gathered[off + 2 * u + 1] as usize;
            }
            off += counts[g];
        }
        self.sharded = Some(ShardedExchange {
            owned_src,
            nnz_table,
            slot,
        });
        full_norms
    }

    /// Shared prologue of the blocking and posted fragment exchanges:
    /// deduplicate the rows, partition them by owning group, compute the
    /// a-priori ring counts, and pack this cell's fragments. `None` for
    /// replicated cells (no exchange).
    fn exchange_plan(&self, rows: &[usize]) -> Option<ExchangePlan> {
        let sh = self.sharded.as_ref()?;
        let pr = self.owned.len();
        let mut uniq = rows.to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        let mut per_group: Vec<Vec<usize>> = vec![Vec::new(); pr];
        for &t in &uniq {
            per_group[(t / self.row_block) % pr].push(t);
        }
        let counts: Vec<usize> = per_group
            .iter()
            .map(|g| g.iter().map(|&t| 2 * sh.nnz_table[t]).sum())
            .collect();
        // My fragments: owned rows are ascending, so each global row's
        // local index is its insertion point.
        let my_rows = &self.owned[self.my_group];
        let locals: Vec<usize> = per_group[self.my_group]
            .iter()
            .map(|&t| {
                let u = my_rows.partition_point(|&r| r < t);
                debug_assert_eq!(my_rows[u], t, "row {t} not owned by this group");
                u
            })
            .collect();
        let mine = sh.owned_src.pack_rows(&locals);
        let mut order = Vec::with_capacity(uniq.len());
        let mut row_nnz = Vec::with_capacity(uniq.len());
        for g in &per_group {
            for &t in g {
                order.push(t);
                row_nnz.push(sh.nnz_table[t]);
            }
        }
        let my_group_rows = std::mem::take(&mut per_group[self.my_group]);
        Some(ExchangePlan {
            order,
            row_nnz,
            counts,
            locals,
            mine,
            my_group_rows,
        })
    }

    /// Rebuild the gathered fragments ([`Csr::from_packed`]) and publish
    /// them through the [`FragmentSlot`] with the global-row → fragment
    /// map.
    fn publish_fragments(&self, order: Vec<usize>, row_nnz: Vec<usize>, gathered: &[f64]) {
        let sh = self
            .sharded
            .as_ref()
            .expect("publish_fragments on a replicated cell");
        let fragments = Csr::from_packed(sh.owned_src.ncols(), &row_nnz, gathered);
        let pos: HashMap<usize, usize> = order.iter().enumerate().map(|(i, &t)| (t, i)).collect();
        sh.slot.fill(fragments, pos);
    }
}

/// A planned fragment exchange: everything both the blocking and the
/// posted paths need, computed before any traffic moves.
struct ExchangePlan {
    /// Deduplicated sampled rows in group-major (gathered) order.
    order: Vec<usize>,
    /// Stored-entry count of each row of `order`.
    row_nnz: Vec<usize>,
    /// Ring contribution counts per row group (words).
    counts: Vec<usize>,
    /// Local (owned-CSR) indices of this cell's sampled rows.
    locals: Vec<usize>,
    /// This cell's packed fragments (`Csr::pack_rows` of `locals`).
    mine: Vec<f64>,
    /// The global rows behind `locals`, in packed order.
    my_group_rows: Vec<usize>,
}

impl<'c, C: Communicator> ReduceStage for GridReduce<'c, C> {
    fn is_active(&self) -> bool {
        true
    }

    fn reduce(&mut self, buf: &mut [f64]) {
        let m = self.m;
        assert_eq!(buf.len() % m, 0, "grid reduce: buffer must be k x m");
        let k = buf.len() / m;
        let w = self.owned[self.my_group].len();
        // 1. Pack the per-row partial prefixes (GridProduct's contract).
        self.packed.clear();
        self.packed.resize(k * w, 0.0);
        for r in 0..k {
            self.packed[r * w..(r + 1) * w].copy_from_slice(&buf[r * m..r * m + w]);
        }
        // 2. Sum the pc feature-shard partials over the column subcomm.
        {
            let mut sub = SubComm::new(&mut *self.comm, &self.col_members, &mut self.col_stats);
            allreduce_sum(&mut sub, &mut self.packed, self.algo);
        }
        // 3. Allgather the pr reduced slices along the row subcomm and
        //    scatter them into the full row-major k×m block.
        let counts: Vec<usize> = self.owned.iter().map(|o| k * o.len()).collect();
        let gathered = {
            let mut sub = SubComm::new(&mut *self.comm, &self.row_members, &mut self.row_stats);
            allgatherv(&mut sub, &self.packed, &counts)
        };
        let mut off = 0usize;
        for (g, rows) in self.owned.iter().enumerate() {
            let wg = rows.len();
            for r in 0..k {
                let slice = &gathered[off + r * wg..off + (r + 1) * wg];
                for (u, &t) in rows.iter().enumerate() {
                    buf[r * m + t] = slice[u];
                }
            }
            off += counts[g];
        }
    }

    fn stats(&self) -> CommStats {
        self.col_stats.plus(self.row_stats).plus(self.exch_stats)
    }

    /// The sharded layout's pre-product **fragment exchange**: assemble
    /// the sampled rows' fragments from the `pr` cells of this feature
    /// shard so the product can run exactly as if the full shard were
    /// local.
    ///
    /// 1. Deduplicate the rows (sorted — identical on every rank, since
    ///    all ranks see the same deterministic sample stream) and
    ///    partition them by owning row group (the block-cyclic map).
    /// 2. Pack this cell's owned fragments ([`Csr::pack_rows`]:
    ///    interleaved `(column, value)` pairs, verbatim stored entries).
    /// 3. One ring [`allgatherv`] over the row subcommunicator — counts
    ///    `2·Σ nnz` per group are computed locally from the setup nnz
    ///    table, so the schedule is agreed a priori.
    /// 4. Rebuild the fragments ([`Csr::from_packed`]) and publish them
    ///    through the [`FragmentSlot`] with the global-row → fragment
    ///    map.
    ///
    /// No-op for replicated cells. Traffic lands in
    /// [`Self::exch_stats`], attributed by the engine to
    /// [`crate::costmodel::Phase::FragmentExchange`].
    fn exchange(&mut self, rows: &[usize]) {
        let Some(plan) = self.exchange_plan(rows) else {
            return;
        };
        let gathered = {
            let mut sub = SubComm::new(&mut *self.comm, &self.row_members, &mut self.exch_stats);
            allgatherv(&mut sub, &plan.mine, &plan.counts)
        };
        // Rebuild in group-major order (the gathered layout) and map
        // global rows to fragment positions.
        self.publish_fragments(plan.order, plan.row_nnz, &gathered);
    }

    fn has_exchange(&self) -> bool {
        self.sharded.is_some()
    }

    /// Posted fragment exchange: publish this cell's *own* fragments
    /// immediately (verbatim the same stored rows the blocking exchange
    /// would deliver, so the owned-rows product pass is bitwise
    /// unchanged), post the ring, and hand back its planned traffic.
    fn exchange_start(&mut self, rows: &[usize]) -> CommStats {
        let Some(plan) = self.exchange_plan(rows) else {
            return CommStats::default();
        };
        assert!(
            self.pending_exchange.is_none(),
            "exchange_start: an exchange is already in flight"
        );
        {
            let sh = self.sharded.as_ref().expect("exchange_plan implies sharded");
            let local_frags = sh.owned_src.gather_rows(&plan.locals);
            let local_pos: HashMap<usize, usize> = plan
                .my_group_rows
                .iter()
                .enumerate()
                .map(|(i, &t)| (t, i))
                .collect();
            sh.slot.fill(local_frags, local_pos);
        }
        let handle = {
            let mut sub = SubComm::new(&mut *self.comm, &self.row_members, &mut self.exch_stats);
            CollectiveHandle::post_allgatherv(&mut sub, &plan.mine, &plan.counts)
        };
        let posted = handle.posted_stats();
        self.pending_exchange = Some(PendingExchange {
            handle,
            order: plan.order,
            row_nnz: plan.row_nnz,
        });
        posted
    }

    fn exchange_finish(&mut self) {
        let Some(mut pending) = self.pending_exchange.take() else {
            return;
        };
        let gathered = {
            let mut sub = SubComm::new(&mut *self.comm, &self.row_members, &mut self.exch_stats);
            pending.handle.wait(&mut sub)
        };
        self.publish_fragments(pending.order, pending.row_nnz, &gathered);
    }

    fn local_mask(&self, rows: &[usize]) -> Vec<bool> {
        if self.sharded.is_none() {
            return vec![false; rows.len()];
        }
        let pr = self.owned.len();
        rows.iter()
            .map(|&t| (t / self.row_block) % pr == self.my_group)
            .collect()
    }

    /// Posted column reduce (pipeline overlap): pack the partial
    /// prefixes and post the column-subcommunicator allreduce. The row
    /// allgather + scatter stay in [`Self::reduce_finish`] — they need
    /// the reduced payload, so they are the *exposed* tail.
    fn reduce_start(&mut self, buf: &[f64]) -> CommStats {
        assert!(
            self.pending_reduce.is_none(),
            "reduce_start: a reduction is already in flight"
        );
        let m = self.m;
        assert_eq!(buf.len() % m, 0, "grid reduce: buffer must be k x m");
        let k = buf.len() / m;
        let w = self.owned[self.my_group].len();
        self.packed.clear();
        self.packed.resize(k * w, 0.0);
        for r in 0..k {
            self.packed[r * w..(r + 1) * w].copy_from_slice(&buf[r * m..r * m + w]);
        }
        let packed = std::mem::take(&mut self.packed);
        let handle = {
            let mut sub = SubComm::new(&mut *self.comm, &self.col_members, &mut self.col_stats);
            CollectiveHandle::post_allreduce(&mut sub, packed, self.algo)
        };
        let posted = handle.posted_stats();
        self.pending_reduce = Some((handle, k));
        posted
    }

    fn reduce_finish(&mut self, buf: &mut [f64]) {
        let (mut handle, k) = self
            .pending_reduce
            .take()
            .expect("reduce_finish without a matching reduce_start");
        let m = self.m;
        assert_eq!(
            buf.len(),
            k * m,
            "reduce_finish: block shape changed since reduce_start"
        );
        let reduced = {
            let mut sub = SubComm::new(&mut *self.comm, &self.col_members, &mut self.col_stats);
            handle.wait(&mut sub)
        };
        // Exposed tail — identical to the blocking reduce's step 3.
        let counts: Vec<usize> = self.owned.iter().map(|o| k * o.len()).collect();
        let gathered = {
            let mut sub = SubComm::new(&mut *self.comm, &self.row_members, &mut self.row_stats);
            allgatherv(&mut sub, &reduced, &counts)
        };
        let mut off = 0usize;
        for (g, rows) in self.owned.iter().enumerate() {
            let wg = rows.len();
            for r in 0..k {
                let slice = &gathered[off + r * wg..off + (r + 1) * wg];
                for (u, &t) in rows.iter().enumerate() {
                    buf[r * m + t] = slice[u];
                }
            }
            off += counts[g];
        }
        // Reclaim the packed buffer's allocation for the next call.
        self.packed = reduced;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_ranks;

    #[test]
    fn no_reduce_is_inert() {
        let mut r = NoReduce;
        let mut buf = vec![1.0, 2.0];
        r.reduce(&mut buf);
        assert!(!r.is_active());
        assert_eq!(buf, vec![1.0, 2.0]);
        assert_eq!(r.stats(), CommStats::default());
    }

    /// End-to-end grid reduce over a 2×2 grid: packed prefixes in, fully
    /// reduced and reassembled k×m blocks out, with traffic split between
    /// the column and row subcommunicators.
    #[test]
    fn grid_reduce_sums_over_columns_and_reassembles_rows() {
        let (pr, pc, m, k) = (2usize, 2usize, 5usize, 2usize);
        let outs = run_ranks(pr * pc, |c| {
            let rank = c.rank();
            let (i, j) = (rank / pc, rank % pc);
            let mut stage =
                GridReduce::new(c, AllreduceAlgo::RecursiveDoubling, pr, pc, m, 1);
            assert!(stage.is_active());
            let owned: Vec<usize> = stage.owned_rows().to_vec();
            // Fill per the GridProduct packed-prefix contract: garbage
            // beyond the prefix must be overwritten by the reduce.
            let mut buf = vec![f64::NAN; k * m];
            for r in 0..k {
                for (u, &t) in owned.iter().enumerate() {
                    buf[r * m + u] = ((j + 1) * 100 + r * 10 + t) as f64;
                }
            }
            stage.reduce(&mut buf);
            (buf, i, stage.col_stats(), stage.row_stats())
        });
        for (buf, _i, col, row) in &outs {
            for r in 0..k {
                for t in 0..m {
                    // Σ over the two feature shards of (j+1)·100 + r·10 + t.
                    let expect = 300.0 + 2.0 * (r * 10 + t) as f64;
                    assert_eq!(buf[r * m + t], expect, "({r},{t})");
                }
            }
            assert_eq!(col.allreduces, 1);
            assert!(col.words > 0 && row.words > 0);
            assert_eq!(row.allreduces, 0, "the allgather is not an allreduce");
        }
        // Row groups own {0,2,4} and {1,3}: rank 0's reduce payload is
        // k·3 words (recursive doubling over pc=2 sends it once), and the
        // two-rank allgather ring sends its own k·3-word slice once.
        let (_, _, col0, row0) = &outs[0];
        assert_eq!(col0.words, (k * 3) as u64);
        assert_eq!(row0.words, (k * 3) as u64);
    }

    /// The split reduce (`reduce_start` + interleaved "compute" +
    /// `reduce_finish`) produces bitwise the same block and the same
    /// per-subcommunicator traffic as the blocking `reduce`, with the
    /// column-reduce share reported as posted.
    #[test]
    fn posted_grid_reduce_matches_blocking_bitwise_and_in_stats() {
        let (pr, pc, m, k) = (2usize, 3usize, 7usize, 2usize);
        let fill = |j: usize, owned: &[usize]| {
            let mut buf = vec![f64::NAN; k * m];
            for r in 0..k {
                for (u, &t) in owned.iter().enumerate() {
                    buf[r * m + u] = ((j + 1) * 100 + r * 10 + t) as f64;
                }
            }
            buf
        };
        let blocking = run_ranks(pr * pc, |c| {
            let j = c.rank() % pc;
            let mut stage = GridReduce::new(c, AllreduceAlgo::Rabenseifner, pr, pc, m, 1);
            let owned: Vec<usize> = stage.owned_rows().to_vec();
            let mut buf = fill(j, &owned);
            stage.reduce(&mut buf);
            (buf, stage.col_stats(), stage.row_stats())
        });
        let posted = run_ranks(pr * pc, |c| {
            let j = c.rank() % pc;
            let mut stage = GridReduce::new(c, AllreduceAlgo::Rabenseifner, pr, pc, m, 1);
            let owned: Vec<usize> = stage.owned_rows().to_vec();
            let mut buf = fill(j, &owned);
            let planned = stage.reduce_start(&buf);
            stage.reduce_finish(&mut buf);
            (buf, stage.col_stats(), stage.row_stats(), planned)
        });
        for (rank, ((bbuf, bcol, brow), (nbuf, ncol, nrow, planned))) in
            blocking.iter().zip(&posted).enumerate()
        {
            assert_eq!(bbuf, nbuf, "rank {rank}: block bits");
            assert_eq!(bcol, ncol, "rank {rank}: column traffic");
            assert_eq!(brow, nrow, "rank {rank}: row traffic");
            assert_eq!(
                planned, ncol,
                "rank {rank}: the posted share is exactly the column reduce"
            );
        }
    }

    #[test]
    fn allreduce_stage_sums_and_counts() {
        let outs = run_ranks(4, |c| {
            let mut stage = AllreduceSum::new(c, AllreduceAlgo::RecursiveDoubling);
            assert!(stage.is_active());
            let mut buf = vec![stage.rank() as f64 + 1.0; 8];
            stage.reduce(&mut buf);
            (buf, stage.stats())
        });
        for (buf, stats) in &outs {
            assert!(buf.iter().all(|&v| v == 10.0));
            assert_eq!(stats.allreduces, 1);
            assert_eq!(stats.words, 8 * 2); // w·log2(4)
        }
    }
}
