//! Kernel functions (Table 1 of the paper) applied to gram blocks.
//!
//! All three kernels are computed from the *linear* gram product
//! `Z[r][i] = <a_sample_r, a_i>`: the polynomial map is pointwise
//! `(c + z)^d`, and the RBF map expands
//! `‖a_r − a_i‖² = ‖a_r‖² + ‖a_i‖² − 2 z` using cached row norms — the
//! same dot-product expansion the paper uses so the kernel reduces to a
//! (sparse) GEMM plus a pointwise epilogue. That structure is what makes
//! the distributed algorithm work: the GEMM part is linear in the column
//! shards (allreduce-able), the nonlinearity is applied redundantly after
//! the reduction.

#![forbid(unsafe_code)]

use crate::dense::Mat;

/// Kernel choice and parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// `K(a, b) = aᵀb`
    Linear,
    /// `K(a, b) = (c + aᵀb)^d`, `c ≥ 0`, `d ≥ 2`
    Poly {
        /// Additive constant `c ≥ 0`.
        c: f64,
        /// Degree `d ≥ 2`.
        d: i32,
    },
    /// `K(a, b) = exp(−σ‖a−b‖²)`, `σ > 0`
    Rbf {
        /// Width `σ > 0`.
        sigma: f64,
    },
}

impl Kernel {
    /// The paper's convergence-experiment polynomial: `d=3, c=0`.
    pub fn paper_poly() -> Kernel {
        Kernel::Poly { c: 0.0, d: 3 }
    }

    /// The paper's convergence-experiment RBF: `σ=1`.
    pub fn paper_rbf() -> Kernel {
        Kernel::Rbf { sigma: 1.0 }
    }

    /// Short identifier used in configs, artifact names and reports.
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Linear => "linear",
            Kernel::Poly { .. } => "poly",
            Kernel::Rbf { .. } => "rbf",
        }
    }

    /// Parse from config syntax: `linear`, `poly:c=0,d=3`, `rbf:sigma=1`.
    pub fn parse(s: &str) -> Option<Kernel> {
        let (head, rest) = match s.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (s, None),
        };
        match head {
            "linear" => Some(Kernel::Linear),
            "poly" | "polynomial" => {
                let mut c = 0.0;
                let mut d = 3;
                if let Some(r) = rest {
                    for kv in r.split(',') {
                        let (k, v) = kv.split_once('=')?;
                        match k.trim() {
                            "c" => c = v.trim().parse().ok()?,
                            "d" => d = v.trim().parse().ok()?,
                            _ => return None,
                        }
                    }
                }
                Some(Kernel::Poly { c, d })
            }
            "rbf" | "gauss" | "gaussian" => {
                let mut sigma = 1.0;
                if let Some(r) = rest {
                    for kv in r.split(',') {
                        let (k, v) = kv.split_once('=')?;
                        match k.trim() {
                            "sigma" => sigma = v.trim().parse().ok()?,
                            _ => return None,
                        }
                    }
                }
                Some(Kernel::Rbf { sigma })
            }
            _ => None,
        }
    }

    /// Scalar kernel value from a precomputed inner product and squared
    /// norms (the pointwise epilogue).
    #[inline]
    pub fn apply_scalar(&self, dot: f64, norm_a: f64, norm_b: f64) -> f64 {
        match *self {
            Kernel::Linear => dot,
            Kernel::Poly { c, d } => (c + dot).powi(d),
            Kernel::Rbf { sigma } => (-sigma * (norm_a + norm_b - 2.0 * dot).max(0.0)).exp(),
        }
    }

    /// Apply the kernel map in place to a gram block `Z (k×m)` whose entry
    /// `(r, i)` holds `<a_{S_r}, a_i>`; `sample_norms[r] = ‖a_{S_r}‖²`,
    /// `row_norms[i] = ‖a_i‖²` (only read for RBF).
    pub fn apply_block(&self, z: &mut Mat, sample_norms: &[f64], row_norms: &[f64]) {
        assert_eq!(sample_norms.len(), z.nrows());
        self.apply_packed(z.data_mut(), sample_norms, row_norms);
    }

    /// [`Kernel::apply_block`] on a row-major `sample_norms.len() × m`
    /// slice (`m = row_norms.len()`) — the chunk form the threaded
    /// epilogue hands each worker. Per-element map, so identical output
    /// for any whole-row split.
    pub fn apply_packed(&self, z: &mut [f64], sample_norms: &[f64], row_norms: &[f64]) {
        match *self {
            Kernel::Linear => {}
            Kernel::Poly { c, d } => {
                for v in &mut *z {
                    *v = (c + *v).powi(d);
                }
            }
            Kernel::Rbf { sigma } => {
                let m = row_norms.len();
                assert_eq!(z.len(), sample_norms.len() * m);
                for (r, row) in z.chunks_exact_mut(m).enumerate() {
                    let nr = sample_norms[r];
                    for (i, v) in row.iter_mut().enumerate() {
                        let d2 = (nr + row_norms[i] - 2.0 * *v).max(0.0);
                        *v = (-sigma * d2).exp();
                    }
                }
            }
        }
    }

    /// Relative cost `µ` of the nonlinear epilogue per entry, in units of
    /// one fused multiply-add — the paper's Section 4 cost-model scalar.
    /// Calibrated values: `exp`/`pow` are tens of flops-equivalents on the
    /// paper's EPYC target.
    pub fn mu(&self) -> f64 {
        match self {
            Kernel::Linear => 0.0,
            Kernel::Poly { .. } => 12.0,
            Kernel::Rbf { .. } => 30.0,
        }
    }

    /// Flop-equivalents of applying the nonlinear epilogue to a
    /// `rows × m` gram block (the engine's epilogue-stage accounting).
    pub fn epilogue_flops(&self, rows: usize, m: usize) -> f64 {
        self.mu() * rows as f64 * m as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dense::{gemm_nt, Mat};
    use crate::rng::Pcg;

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Kernel::parse("linear"), Some(Kernel::Linear));
        assert_eq!(
            Kernel::parse("poly:c=1.5,d=2"),
            Some(Kernel::Poly { c: 1.5, d: 2 })
        );
        assert_eq!(
            Kernel::parse("rbf:sigma=0.5"),
            Some(Kernel::Rbf { sigma: 0.5 })
        );
        assert_eq!(Kernel::parse("rbf"), Some(Kernel::Rbf { sigma: 1.0 }));
        assert_eq!(Kernel::parse("bogus"), None);
        assert_eq!(Kernel::parse("poly:q=1"), None);
    }

    /// Direct (definition-based) kernel evaluation for the oracle.
    fn kernel_direct(k: &Kernel, a: &[f64], b: &[f64]) -> f64 {
        let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        match *k {
            Kernel::Linear => dot,
            Kernel::Poly { c, d } => (c + dot).powi(d),
            Kernel::Rbf { sigma } => {
                let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
                (-sigma * d2).exp()
            }
        }
    }

    #[test]
    fn apply_block_matches_direct_definition() {
        let mut r = Pcg::seeded(73);
        let kernels = [
            Kernel::Linear,
            Kernel::Poly { c: 0.0, d: 3 },
            Kernel::Poly { c: 1.0, d: 2 },
            Kernel::Rbf { sigma: 1.0 },
            Kernel::Rbf { sigma: 0.1 },
        ];
        for kern in kernels {
            let m = 12;
            let n = 6;
            let a = Mat::from_fn(m, n, |_, _| r.next_gaussian());
            let sample = vec![3usize, 7, 1];
            let a_sample = a.gather_rows(&sample);
            let mut z = Mat::zeros(sample.len(), m);
            gemm_nt(&a_sample, &a, &mut z);
            let rn = a.row_norms_sq();
            let sn: Vec<f64> = sample.iter().map(|&i| rn[i]).collect();
            kern.apply_block(&mut z, &sn, &rn);
            for (rr, &sr) in sample.iter().enumerate() {
                for i in 0..m {
                    let expect = kernel_direct(&kern, a.row(sr), a.row(i));
                    assert!(
                        (z[(rr, i)] - expect).abs() < 1e-10,
                        "{kern:?} ({rr},{i}): {} vs {expect}",
                        z[(rr, i)]
                    );
                }
            }
        }
    }

    #[test]
    fn rbf_diagonal_is_one() {
        let mut r = Pcg::seeded(79);
        let a = Mat::from_fn(5, 4, |_, _| r.next_gaussian());
        let sample: Vec<usize> = (0..5).collect();
        let mut z = Mat::zeros(5, 5);
        gemm_nt(&a, &a, &mut z);
        let rn = a.row_norms_sq();
        Kernel::Rbf { sigma: 2.0 }.apply_block(&mut z, &rn, &rn);
        for (i, &s) in sample.iter().enumerate() {
            assert!((z[(i, s)] - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn mu_ordering() {
        // Cost-model sanity: linear < poly < rbf.
        assert!(Kernel::Linear.mu() < Kernel::paper_poly().mu());
        assert!(Kernel::paper_poly().mu() < Kernel::paper_rbf().mu());
    }
}
