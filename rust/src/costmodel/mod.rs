//! Hockney performance model and cost accounting.
//!
//! Section 4 of the paper analyzes the methods with Hockney's model,
//! `T = γF + βW + φL` (compute, bandwidth, latency). We use the model in
//! two ways:
//!
//! 1. **Projection** — the distributed solvers record *measured* per-rank
//!    counts (flops per phase, words and rounds from real message traffic)
//!    into a [`Ledger`]; [`MachineProfile::project`] weights the
//!    critical-path counts with a Cray-EX-like machine profile to obtain
//!    projected running times. This is how the strong-scaling figures are
//!    regenerated on a single-core box (see DESIGN.md §substitutions).
//! 2. **Analysis** — [`bdcd_cost`] / [`bdcd_sstep_cost`] implement the
//!    closed-form leading-order costs of Theorems 1 and 2, used to
//!    cross-check the measured counts and to reason about the
//!    computation–bandwidth–latency trade-off.

#![forbid(unsafe_code)]

mod theorems;

pub use theorems::{bdcd_cost, bdcd_sstep_cost, dcd_cost, dcd_sstep_cost, AlgoCost, ProblemDims};

use crate::comm::CommStats;
use crate::util::PhaseTimer;

/// Execution phases — the paper's runtime-breakdown categories
/// (Figures 4, 7, 8): kernel computation, allreduce, gradient
/// correction (s-step only), subproblem solve, memory reset, and the
/// solution update — plus [`Phase::CacheHit`], the time spent serving
/// kernel rows out of the gram engine's row cache instead of
/// recomputing (and re-allreducing) them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Sampled gram product + nonlinear kernel map.
    KernelCompute,
    /// The gram reduction collective(s).
    Allreduce,
    /// s-step gradient corrections.
    GradCorr,
    /// Coordinate-subproblem solves.
    Solve,
    /// s-step buffer resets.
    MemReset,
    /// Solution (α) updates.
    Update,
    /// Kernel rows served from the gram engine's row cache.
    CacheHit,
    /// Sampled-row fragment assembly of the sharded 2D grid storage
    /// (`gram::GridStorage::Sharded`): the pre-product ring allgather
    /// that materializes the sampled slice on every cell.
    FragmentExchange,
}

impl Phase {
    /// Every phase, in report order.
    pub const ALL: [Phase; 8] = [
        Phase::KernelCompute,
        Phase::Allreduce,
        Phase::GradCorr,
        Phase::Solve,
        Phase::MemReset,
        Phase::Update,
        Phase::CacheHit,
        Phase::FragmentExchange,
    ];

    /// Short report tag.
    pub fn name(&self) -> &'static str {
        match self {
            Phase::KernelCompute => "kernel",
            Phase::Allreduce => "allreduce",
            Phase::GradCorr => "gradcorr",
            Phase::Solve => "solve",
            Phase::MemReset => "memreset",
            Phase::Update => "update",
            Phase::CacheHit => "cachehit",
            Phase::FragmentExchange => "exchange",
        }
    }

    fn idx(&self) -> usize {
        *self as usize
    }
}

const NPHASE: usize = 8;

/// Row-cache accounting for the gram engine (see `crate::gram`): how many
/// sampled rows were served from cache, and the communication that
/// skipping their recompute avoided. All ranks run the same deterministic
/// access stream, so these are identical across ranks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Sampled-row requests served from the cache (or from a duplicate
    /// row earlier in the same block).
    pub hits: u64,
    /// Sampled-row requests that had to be computed.
    pub misses: u64,
    /// Allreduce *payload* f64 words avoided by hits — `m` words per hit
    /// row on a distributed engine, zero on local engines (nothing to
    /// save). The wire savings are algorithm-dependent (e.g. recursive
    /// doubling sends `payload·log₂P` words per rank).
    pub words_saved: u64,
    /// Whole allreduces skipped because *every* row of a gram call hit.
    pub allreduces_saved: u64,
}

impl CacheStats {
    /// Elementwise max — the critical path over ranks.
    pub fn max(self, other: CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.max(other.hits),
            misses: self.misses.max(other.misses),
            words_saved: self.words_saved.max(other.words_saved),
            allreduces_saved: self.allreduces_saved.max(other.allreduces_saved),
        }
    }

    /// Avoided allreduce payload in bytes (f64 words × 8).
    pub fn bytes_saved(&self) -> u64 {
        self.words_saved * 8
    }

    /// Hit fraction over all sampled-row requests (0 when none).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Per-rank cost ledger: flop counts and wall-clock per phase, plus the
/// rank's communication statistics.
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    flops: [f64; NPHASE],
    /// Flops that executed *under* a posted (in-flight) collective — the
    /// overlap modes' hidden-compute budget. Always a subset of `flops`
    /// (hidden work is recorded in both).
    hidden_flops: [f64; NPHASE],
    wall: [PhaseTimer; NPHASE],
    /// Gram-oracle invocations — with [`Self::kernel_rows`], the
    /// projection uses the average rows/call to model the BLAS-1→BLAS-3
    /// memory-bandwidth-efficiency gain of blocked kernel computation
    /// (the paper's Fig. 4 observation that kernel time *falls* with s).
    pub kernel_calls: f64,
    /// Total sampled rows across all gram calls.
    pub kernel_rows: f64,
    /// Inner iterations executed (solver updates). The projection charges
    /// a fixed per-iteration software floor (BLAS-1 dispatch, projection
    /// bookkeeping) against it — the cost the paper's runtime breakdown
    /// shows as non-zero solve/memory slices even for tiny datasets.
    pub iters: f64,
    /// Copied from the rank's communicator at the end of a run.
    pub comm: CommStats,
    /// The share of the traffic that was *posted* (nonblocking) rather
    /// than waited on inline — the collectives the overlap modes hide
    /// under compute (`gram::OverlapMode`). Strictly a subset of the
    /// totals: every posted word/round is also counted in `comm` (and in
    /// the grid sub-stats), so the totals stay overlap-invariant; this
    /// field only tells the projection how much of them *may* overlap
    /// with [`Ledger::hidden_flops`]. Zero for blocking runs.
    pub comm_posted: CommStats,
    /// Column-subcommunicator (gram reduce) traffic of a 2D grid run —
    /// the collective the grid shrinks from `P` to `pc` participants.
    /// Zero for local and 1D runs, where `comm` holds everything.
    pub comm_col: CommStats,
    /// Row-subcommunicator (slice allgather) traffic of a 2D grid run.
    /// Zero for local and 1D runs.
    pub comm_row: CommStats,
    /// Fragment-exchange traffic of a sharded-storage 2D grid run
    /// (`gram::GridStorage::Sharded`): the setup ring plus the per-call
    /// sampled-row rings over the row subcommunicator. Included in
    /// `comm` (which stays the grand per-rank total); zero for local,
    /// 1D and replicated-grid runs.
    pub comm_exch: CommStats,
    /// Gram-engine row-cache accounting (all zeros with the cache off).
    pub cache: CacheStats,
    /// Per-rank resident-memory model in f64 words (data shard + row
    /// cache + solver/engine scratch; see
    /// `coordinator::scaling::mem_words_per_rank`). Identical between
    /// the measured and analytic engines — both call the same model —
    /// and surfaced as the scaling table's memory column and the
    /// auto-tuner's `--mem-limit` feasibility input. Zero when no run
    /// populated it.
    pub mem_words: u64,
}

impl Ledger {
    /// An all-zero ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `n` flop-equivalents against `phase` (kernel-map `µ` factors
    /// are already folded in by the caller).
    #[inline]
    pub fn add_flops(&mut self, phase: Phase, n: f64) {
        self.flops[phase.idx()] += n;
    }

    /// Record `n` flop-equivalents of `phase` as having executed under a
    /// posted collective (on top of, not instead of,
    /// [`Ledger::add_flops`] — the caller records the work normally and
    /// additionally marks it hidden).
    #[inline]
    pub fn add_hidden_flops(&mut self, phase: Phase, n: f64) {
        self.hidden_flops[phase.idx()] += n;
    }

    /// Flop-equivalents of `phase` recorded as overlap-hidden.
    pub fn hidden_flops(&self, phase: Phase) -> f64 {
        self.hidden_flops[phase.idx()]
    }

    /// Record the traffic of a collective that was posted (nonblocking)
    /// rather than waited on inline. The same traffic is also counted in
    /// the blocking totals by the communicator — this marks it
    /// overlappable, it does not move it.
    pub fn add_posted(&mut self, stats: CommStats) {
        self.comm_posted = self.comm_posted.plus(stats);
    }

    /// Record one gram-oracle call over `rows` sampled rows.
    #[inline]
    pub fn add_kernel_call(&mut self, rows: usize) {
        self.kernel_calls += 1.0;
        self.kernel_rows += rows as f64;
    }

    /// Time a closure against `phase` (wall clock) — the measured local
    /// compute signal used to sanity-check γ.
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        self.wall[phase.idx()].time(f)
    }

    /// Flop-equivalents recorded against `phase`.
    pub fn flops(&self, phase: Phase) -> f64 {
        self.flops[phase.idx()]
    }

    /// Flop-equivalents across all phases.
    pub fn total_flops(&self) -> f64 {
        self.flops.iter().sum()
    }

    /// Achieved compute rate over an externally measured wall-clock
    /// interval (the serve loop reports Gflop/s from this; training
    /// reports use the per-phase projections instead). Zero when the
    /// interval is degenerate.
    pub fn flops_per_sec(&self, wall_secs: f64) -> f64 {
        if wall_secs > 0.0 {
            self.total_flops() / wall_secs
        } else {
            0.0
        }
    }

    /// Measured wall-clock seconds of `phase` on this rank.
    pub fn wall_secs(&self, phase: Phase) -> f64 {
        self.wall[phase.idx()].secs()
    }

    /// Measured wall-clock seconds across all phases.
    pub fn total_wall_secs(&self) -> f64 {
        self.wall.iter().map(|t| t.secs()).sum()
    }

    /// Critical-path merge: elementwise max of flops and wall, max of comm.
    /// (All ranks advance in lockstep between allreduces, so the slowest
    /// rank per phase bounds the phase — this is what surfaces the
    /// news20.binary load imbalance.)
    pub fn critical_path(ledgers: &[Ledger]) -> Ledger {
        let mut out = Ledger::new();
        for l in ledgers {
            for i in 0..NPHASE {
                out.flops[i] = out.flops[i].max(l.flops[i]);
                out.hidden_flops[i] = out.hidden_flops[i].max(l.hidden_flops[i]);
                if l.wall[i].secs() > out.wall[i].secs() {
                    out.wall[i] = l.wall[i].clone();
                }
            }
            out.kernel_calls = out.kernel_calls.max(l.kernel_calls);
            out.kernel_rows = out.kernel_rows.max(l.kernel_rows);
            out.iters = out.iters.max(l.iters);
            out.comm = out.comm.max(l.comm);
            out.comm_posted = out.comm_posted.max(l.comm_posted);
            out.comm_col = out.comm_col.max(l.comm_col);
            out.comm_row = out.comm_row.max(l.comm_row);
            out.comm_exch = out.comm_exch.max(l.comm_exch);
            out.cache = out.cache.max(l.cache);
            out.mem_words = out.mem_words.max(l.mem_words);
        }
        out
    }

    /// The per-rank resident-memory model in f64 words (see
    /// [`Ledger::mem_words`]).
    pub fn mem_per_rank(&self) -> u64 {
        self.mem_words
    }
}

/// Hockney machine parameters: `γ` seconds per flop, `β` seconds per f64
/// word moved, `φ` seconds per message.
#[derive(Clone, Copy, Debug)]
pub struct MachineProfile {
    /// Profile tag (`cray-ex`, `cloud`).
    pub name: &'static str,
    /// Seconds per flop.
    pub gamma: f64,
    /// Seconds per f64 word moved.
    pub beta: f64,
    /// Seconds per message (latency).
    pub phi: f64,
    /// Relative cost of a nonlinear kernel-map op (exp/pow) vs an FMA is
    /// carried by `Kernel::mu()`; profiles may scale it.
    pub mu_scale: f64,
    /// Effective slowdown of a 1-row gram computation vs a large blocked
    /// one (BLAS-1/2 streams `A` per sampled row; blocking amortizes the
    /// stream — the paper's Fig. 4 "better single-node memory-bandwidth
    /// utilization"). The projection charges the kernel phase
    /// `γ · flops · (1 + (penalty−1)/avg_rows_per_call)`.
    pub blas1_penalty: f64,
    /// Fixed per-inner-iteration software floor (seconds): BLAS-call
    /// dispatch and solver bookkeeping, which dominate the s-step
    /// method's per-iteration cost once communication is amortized.
    pub iter_overhead: f64,
    /// Cores available to one rank for the intra-rank threaded product
    /// (`parallel::ParallelProduct`): [`Self::project_hybrid`] caps the
    /// kernel-phase speedup of `t` worker threads at this count. One
    /// rank rarely owns the whole socket in an MPI×threads launch, so
    /// this is cores-per-process, not cores-per-node.
    pub cores_per_rank: usize,
}

impl MachineProfile {
    /// A Cray-EX-like profile (AMD EPYC 7763 + Slingshot), calibrated to
    /// the regimes in the paper: per-process effective compute ≈ 4 GF/s
    /// on BLAS-1/2-ish sparse kernels, per-process effective injection
    /// bandwidth ≈ 2 GB/s, small-message allreduce step latency ≈ 5 µs.
    pub fn cray_ex() -> MachineProfile {
        MachineProfile {
            name: "cray-ex",
            gamma: 2.5e-10,
            beta: 4.0e-9,
            phi: 5.0e-6,
            mu_scale: 1.0,
            blas1_penalty: 4.0,
            iter_overhead: 5.0e-6,
            cores_per_rank: 16,
        }
    }

    /// A cloud/federated-like profile (the paper's future-work setting):
    /// two orders of magnitude worse latency, one order worse bandwidth.
    pub fn cloud() -> MachineProfile {
        MachineProfile {
            name: "cloud",
            gamma: 2.5e-10,
            beta: 4.0e-8,
            phi: 5.0e-4,
            mu_scale: 1.0,
            blas1_penalty: 4.0,
            iter_overhead: 5.0e-6,
            cores_per_rank: 8,
        }
    }

    /// Parse a machine spec: a named profile (`cray-ex`, `cloud`),
    /// optionally followed by `:key=value,key=value` overrides — e.g.
    /// `cray-ex:alpha=1e-5,beta=4e-9,gamma=2.5e-10,cores=32` — or a
    /// saved calibration, `profile:<path>` (see [`Self::load`] and
    /// `kcd tune --calibrate`). Override keys use the
    /// communication-model spelling: `alpha` is seconds per
    /// message (Hockney `φ`), `beta` seconds per f64 word, `gamma`
    /// seconds per flop, and `cores` the per-rank core budget the
    /// auto-tuner may spend on threads.
    ///
    /// Validation follows the strict `Config::try_*` convention: a
    /// present-but-malformed, non-finite, or non-positive value is a
    /// hard error naming the key (`'machine.alpha'`), never a silent
    /// fallback to the base profile's value.
    pub fn parse(spec: &str) -> Result<MachineProfile, String> {
        if let Some(path) = spec.strip_prefix("profile:") {
            return MachineProfile::load(std::path::Path::new(path.trim()));
        }
        let (base, overrides) = match spec.split_once(':') {
            Some((b, o)) => (b.trim(), Some(o)),
            None => (spec.trim(), None),
        };
        let mut profile = match base {
            "cray-ex" => MachineProfile::cray_ex(),
            "cloud" => MachineProfile::cloud(),
            other => {
                return Err(format!(
                    "invalid value for 'machine': unknown profile '{other}' \
                     (known: cray-ex, cloud, profile:<path>; overrides: \
                     :alpha=..,beta=..,gamma=..,cores=..)"
                ))
            }
        };
        let Some(overrides) = overrides else {
            return Ok(profile);
        };
        for pair in overrides.split(',') {
            let pair = pair.trim();
            let Some((key, raw)) = pair.split_once('=') else {
                return Err(format!(
                    "invalid value for 'machine': override '{pair}' is not key=value"
                ));
            };
            let (key, raw) = (key.trim(), raw.trim());
            match key {
                "alpha" | "beta" | "gamma" => {
                    let v: f64 = raw.parse().map_err(|_| {
                        format!(
                            "invalid value for 'machine.{key}': expected a number, got '{raw}'"
                        )
                    })?;
                    if !v.is_finite() || v <= 0.0 {
                        return Err(format!(
                            "invalid value for 'machine.{key}': expected a positive \
                             number of seconds, got '{raw}'"
                        ));
                    }
                    match key {
                        "alpha" => profile.phi = v,
                        "beta" => profile.beta = v,
                        _ => profile.gamma = v,
                    }
                }
                "cores" => {
                    let v: usize = raw.parse().map_err(|_| {
                        format!(
                            "invalid value for 'machine.cores': expected a positive \
                             integer, got '{raw}'"
                        )
                    })?;
                    if v == 0 {
                        return Err(
                            "invalid value for 'machine.cores': expected a positive \
                             integer, got '0'"
                                .to_string(),
                        );
                    }
                    profile.cores_per_rank = v;
                }
                other => {
                    return Err(format!(
                        "invalid value for 'machine': unknown override key '{other}' \
                         (known: alpha, beta, gamma, cores)"
                    ))
                }
            }
        }
        Ok(profile)
    }

    /// Serialize to the TOML-subset profile format [`Self::load`]
    /// reads (the same `key = value` grammar as `--config` files,
    /// parsed by `coordinator::Config`). Floats are printed with `{:e}`
    /// — Rust's shortest-round-trip representation — so a save → load
    /// cycle reproduces every field bit for bit (pinned by a test).
    pub fn to_profile_string(&self) -> String {
        format!(
            "# kcd machine profile (written by `kcd tune --calibrate`)\n\
             # load with: --machine profile:<this file>\n\
             profile = \"{}\"\n\
             alpha = {:e}\n\
             beta = {:e}\n\
             gamma = {:e}\n\
             mu-scale = {:e}\n\
             blas1-penalty = {:e}\n\
             iter-overhead = {:e}\n\
             cores = {}\n",
            self.name,
            self.phi,
            self.beta,
            self.gamma,
            self.mu_scale,
            self.blas1_penalty,
            self.iter_overhead,
            self.cores_per_rank,
        )
    }

    /// Write the profile to `path` in the [`Self::load`] format.
    pub fn save(&self, path: &std::path::Path) -> Result<(), String> {
        std::fs::write(path, self.to_profile_string())
            .map_err(|e| format!("cannot write machine profile '{}': {e}", path.display()))
    }

    /// Load a saved profile (`--machine profile:<path>`; written by
    /// [`Self::save`] from `kcd tune --calibrate`, or by hand).
    pub fn load(path: &std::path::Path) -> Result<MachineProfile, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read machine profile '{}': {e}", path.display()))?;
        Self::from_profile_string(&text)
            .map_err(|e| format!("machine profile '{}': {e}", path.display()))
    }

    /// Parse the profile file format: TOML-subset `key = value` with
    /// required `alpha` / `beta` / `gamma` / `cores` and optional
    /// `mu-scale` / `blas1-penalty` / `iter-overhead` (defaulting to
    /// the [`Self::cray_ex`] shape parameters) plus an optional
    /// `profile` name tag. Strict `Config::try_*` semantics: an absent
    /// optional key falls back, but a present-and-malformed, missing
    /// required, non-finite, or non-positive value is a hard error
    /// naming the key.
    pub fn from_profile_string(text: &str) -> Result<MachineProfile, String> {
        let cfg = crate::coordinator::Config::parse(text)?;
        let base = MachineProfile::cray_ex();
        let require = |key: &str| -> Result<f64, String> {
            let v = cfg
                .try_f64(key)?
                .ok_or_else(|| format!("missing required key '{key}'"))?;
            if !v.is_finite() || v <= 0.0 {
                return Err(format!(
                    "invalid value for '{key}': expected a positive number of \
                     seconds, got {v}"
                ));
            }
            Ok(v)
        };
        let optional = |key: &str, default: f64| -> Result<f64, String> {
            match cfg.try_f64(key)? {
                None => Ok(default),
                Some(v) if v.is_finite() && v > 0.0 => Ok(v),
                Some(v) => Err(format!(
                    "invalid value for '{key}': expected a positive number, got {v}"
                )),
            }
        };
        let phi = require("alpha")?;
        let beta = require("beta")?;
        let gamma = require("gamma")?;
        let cores_per_rank = cfg
            .try_usize("cores")?
            .ok_or_else(|| "missing required key 'cores'".to_string())?;
        if cores_per_rank == 0 {
            return Err("invalid value for 'cores': expected a positive integer, got 0".into());
        }
        // `name` stays `&'static str` (the profile is `Copy` and shared
        // by value throughout the tuner): known tags map back to their
        // static names, anything else is a calibrated profile.
        let name = match cfg.try_str("profile")?.unwrap_or("calibrated") {
            "cray-ex" => "cray-ex",
            "cloud" => "cloud",
            _ => "calibrated",
        };
        Ok(MachineProfile {
            name,
            gamma,
            beta,
            phi,
            mu_scale: optional("mu-scale", base.mu_scale)?,
            blas1_penalty: optional("blas1-penalty", base.blas1_penalty)?,
            iter_overhead: optional("iter-overhead", base.iter_overhead)?,
            cores_per_rank,
        })
    }

    /// Words per message at which latency and bandwidth costs are equal —
    /// the machine-balance point that governs the optimal `s`.
    pub fn balance_words(&self) -> f64 {
        self.phi / self.beta
    }

    /// Project a critical-path ledger onto this machine: returns per-phase
    /// projected seconds. Compute phases use `γ·flops`; the allreduce
    /// phase uses `β·words + φ·rounds` from the measured traffic, with
    /// the sharded grid storage's fragment-exchange share
    /// (`comm_exch ⊆ comm`) split out into its own phase so the
    /// breakdown shows what the memory sharding costs on the wire.
    pub fn project(&self, critical: &Ledger) -> Projection {
        let mut per_phase = [0.0; NPHASE];
        for ph in Phase::ALL {
            per_phase[ph.idx()] = self.gamma * critical.flops(ph);
        }
        // Memory-bandwidth efficiency of the gram computation improves
        // with the average sampled-row block size (see `blas1_penalty`).
        if critical.kernel_calls > 0.0 && critical.kernel_rows > 0.0 {
            let avg_rows = critical.kernel_rows / critical.kernel_calls;
            let factor = 1.0 + (self.blas1_penalty - 1.0) / avg_rows;
            per_phase[Phase::KernelCompute.idx()] *= factor;
        }
        // `comm` is the grand total; saturating keeps a hand-built
        // ledger with exchange-only counters from underflowing.
        let ex = critical.comm_exch;
        per_phase[Phase::Allreduce.idx()] +=
            self.beta * critical.comm.words.saturating_sub(ex.words) as f64
                + self.phi * critical.comm.rounds.saturating_sub(ex.rounds) as f64;
        per_phase[Phase::FragmentExchange.idx()] +=
            self.beta * ex.words as f64 + self.phi * ex.rounds as f64;
        per_phase[Phase::Solve.idx()] += self.iter_overhead * critical.iters;
        Projection {
            per_phase,
            comm: critical.comm,
            overlap_saved_secs: self.overlap_saved(critical, 1),
        }
    }

    /// Seconds the overlap modes hide: the posted collectives' wire time
    /// and the compute executed under them run concurrently, so the
    /// model charges `max` of the two instead of their sum — i.e. it
    /// subtracts `min(posted_comm, hidden_compute)` from the blocking
    /// total. Zero for blocking runs (nothing posted). The hidden kernel
    /// flops get the same BLAS-1 factor and thread split as the kernel
    /// phase itself, keeping the subtraction consistent with the charge.
    pub fn overlap_saved(&self, critical: &Ledger, threads: usize) -> f64 {
        let posted = critical.comm_posted;
        let posted_secs = self.beta * posted.words as f64 + self.phi * posted.rounds as f64;
        if posted_secs == 0.0 {
            return 0.0;
        }
        let mut hidden = 0.0;
        for ph in Phase::ALL {
            let mut secs = self.gamma * critical.hidden_flops(ph);
            if ph == Phase::KernelCompute {
                if critical.kernel_calls > 0.0 && critical.kernel_rows > 0.0 {
                    let avg_rows = critical.kernel_rows / critical.kernel_calls;
                    secs *= 1.0 + (self.blas1_penalty - 1.0) / avg_rows;
                }
                let t_eff = threads.min(self.cores_per_rank).max(1) as f64;
                secs /= t_eff;
            }
            hidden += secs;
        }
        posted_secs.min(hidden)
    }

    /// Predict a configuration's running time from its critical-path
    /// ledger, split into the Hockney model's three terms — the
    /// auto-tuner's scoring function ([`crate::tune`]).
    ///
    /// This is the same arithmetic as [`Self::project_hybrid`] grouped
    /// differently: the projection buckets seconds by *execution phase*
    /// (so `Allreduce` mixes `β·words` with `φ·rounds`, and `Solve`
    /// mixes `γ·flops` with the per-iteration overhead), while the
    /// prediction buckets the identical terms by *model coefficient* —
    /// compute (`γ`, including the BLAS-1 penalty, the thread split,
    /// and the iteration-overhead floor), bandwidth (`β·words`) and
    /// latency (`φ·rounds`). Totals agree to floating-point rounding;
    /// a test pins the two within 1e-12 relative.
    pub fn predict(&self, critical: &Ledger, threads: usize) -> Predicted {
        let mut compute = 0.0;
        for ph in Phase::ALL {
            let mut secs = self.gamma * critical.flops(ph);
            if ph == Phase::KernelCompute {
                if critical.kernel_calls > 0.0 && critical.kernel_rows > 0.0 {
                    let avg_rows = critical.kernel_rows / critical.kernel_calls;
                    secs *= 1.0 + (self.blas1_penalty - 1.0) / avg_rows;
                }
                let t_eff = threads.min(self.cores_per_rank).max(1) as f64;
                secs /= t_eff;
            }
            compute += secs;
        }
        compute += self.iter_overhead * critical.iters;
        let mut bandwidth = self.beta * critical.comm.words as f64;
        let mut latency = self.phi * critical.comm.rounds as f64;
        // The overlap subtraction, bucketed by what it actually hides:
        // when the posted collectives fit under the hidden compute, the
        // saved seconds are communication (posted words and rounds come
        // off their own coefficients — `overlap_saved` = exactly that
        // sum); otherwise the hidden compute is the smaller side and the
        // saving comes off the compute term. Either way the total drops
        // by the projection's `overlap_saved` scalar, keeping the 1e-12
        // agreement with `project_hybrid`.
        let posted = critical.comm_posted;
        let posted_secs = self.beta * posted.words as f64 + self.phi * posted.rounds as f64;
        if posted_secs > 0.0 {
            let saved = self.overlap_saved(critical, threads);
            if saved >= posted_secs {
                bandwidth -= self.beta * posted.words as f64;
                latency -= self.phi * posted.rounds as f64;
            } else {
                compute -= saved;
            }
        }
        Predicted {
            compute_secs: compute,
            bandwidth_secs: bandwidth,
            latency_secs: latency,
        }
    }

    /// Hybrid (P ranks × t threads) projection: like [`Self::project`]
    /// but with `threads` intra-rank workers splitting the sampled rows
    /// of the gram product, which divides the kernel-compute phase by
    /// the effective worker count `min(threads, cores_per_rank)`. The
    /// flop *counts* are thread-invariant (the ledger is unchanged);
    /// only the phase's projected seconds shrink. The phase also holds
    /// the epilogue flops, which the engine applies on the calling
    /// thread — dividing them too is a deliberate simplification,
    /// acceptable because the epilogue is a small fraction of the phase
    /// (`µ·k·m` vs `2·k·nnz` flops).
    pub fn project_hybrid(&self, critical: &Ledger, threads: usize) -> Projection {
        let mut p = self.project(critical);
        // min-then-max (not clamp) so a degenerate cores_per_rank of 0
        // degrades to serial instead of panicking.
        let t_eff = threads.min(self.cores_per_rank).max(1) as f64;
        p.per_phase[Phase::KernelCompute.idx()] /= t_eff;
        // Hidden kernel compute shrinks with the thread split too, so
        // the overlap saving must be re-derived at this `t`.
        p.overlap_saved_secs = self.overlap_saved(critical, threads);
        p
    }
}

/// Predicted running time of one tuner candidate, split into the
/// Hockney model's coefficient terms (see [`MachineProfile::predict`]).
/// The split is what makes a tuner ranking explainable: a candidate is
/// chosen *because* it trades, say, latency for compute, and the report
/// can show exactly that.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Predicted {
    /// `γ`-weighted seconds: all flop phases (with the BLAS-1 blocking
    /// penalty and the intra-rank thread split applied to the kernel
    /// phase) plus the fixed per-iteration software floor.
    pub compute_secs: f64,
    /// `β`-weighted seconds: critical-path f64 words moved.
    pub bandwidth_secs: f64,
    /// `φ`-weighted seconds: critical-path message rounds.
    pub latency_secs: f64,
}

impl Predicted {
    /// Total predicted seconds (the tuner's ranking key).
    pub fn total_secs(&self) -> f64 {
        self.compute_secs + self.bandwidth_secs + self.latency_secs
    }

    /// The dominant term's report tag (`compute`, `bandwidth`,
    /// `latency`) — ties break toward the earlier tag in that order.
    pub fn dominant(&self) -> &'static str {
        let mut tag = "compute";
        let mut best = self.compute_secs;
        if self.bandwidth_secs > best {
            tag = "bandwidth";
            best = self.bandwidth_secs;
        }
        if self.latency_secs > best {
            tag = "latency";
        }
        tag
    }
}

/// Projected running time, broken down by phase.
#[derive(Clone, Copy, Debug)]
pub struct Projection {
    per_phase: [f64; NPHASE],
    /// The measured traffic the projection weighted.
    pub comm: CommStats,
    /// Seconds hidden by overlapped communication
    /// (`min(posted comm, hidden compute)` — see
    /// [`MachineProfile::overlap_saved`]); already *excluded* from
    /// [`Projection::total_secs`] but not from the per-phase breakdown,
    /// which keeps showing the blocking charge per phase.
    pub overlap_saved_secs: f64,
}

impl Projection {
    /// Projected seconds of one phase.
    pub fn phase_secs(&self, phase: Phase) -> f64 {
        self.per_phase[phase.idx()]
    }

    /// Projected seconds across all phases, net of the overlap saving.
    pub fn total_secs(&self) -> f64 {
        self.per_phase.iter().sum::<f64>() - self.overlap_saved_secs
    }

    /// Markdown table row fragment: per-phase seconds in `Phase::ALL`
    /// order.
    pub fn row(&self) -> String {
        Phase::ALL
            .iter()
            .map(|p| format!("{:.3e}", self.phase_secs(*p)))
            .collect::<Vec<_>>()
            .join(" | ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates_and_merges() {
        let mut a = Ledger::new();
        a.add_flops(Phase::KernelCompute, 100.0);
        a.add_flops(Phase::Solve, 10.0);
        let mut b = Ledger::new();
        b.add_flops(Phase::KernelCompute, 50.0);
        b.add_flops(Phase::GradCorr, 5.0);
        b.comm.words = 42;
        let c = Ledger::critical_path(&[a, b]);
        assert_eq!(c.flops(Phase::KernelCompute), 100.0);
        assert_eq!(c.flops(Phase::GradCorr), 5.0);
        assert_eq!(c.comm.words, 42);
    }

    #[test]
    fn projection_weights_counts() {
        let mut l = Ledger::new();
        l.add_flops(Phase::KernelCompute, 1e9);
        l.comm.words = 1_000_000;
        l.comm.rounds = 100;
        let m = MachineProfile::cray_ex();
        let p = m.project(&l);
        assert!((p.phase_secs(Phase::KernelCompute) - 1e9 * m.gamma).abs() < 1e-12);
        let comm_expect = m.beta * 1e6 + m.phi * 100.0;
        assert!((p.phase_secs(Phase::Allreduce) - comm_expect).abs() < 1e-12);
        assert!(p.total_secs() > 0.0);
    }

    #[test]
    fn cache_stats_merge_and_bytes() {
        let mut a = Ledger::new();
        a.cache.hits = 10;
        a.cache.words_saved = 160;
        let mut b = Ledger::new();
        b.cache.hits = 4;
        b.cache.misses = 7;
        b.cache.allreduces_saved = 2;
        let c = Ledger::critical_path(&[a, b]);
        assert_eq!(c.cache.hits, 10);
        assert_eq!(c.cache.misses, 7);
        assert_eq!(c.cache.allreduces_saved, 2);
        assert_eq!(c.cache.bytes_saved(), 160 * 8);
        assert!((c.cache.hit_rate() - 10.0 / 17.0).abs() < 1e-15);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn cachehit_phase_is_reported_but_costs_nothing_in_projection() {
        let mut l = Ledger::new();
        l.cache.hits = 5;
        let p = MachineProfile::cray_ex().project(&l);
        assert_eq!(p.phase_secs(Phase::CacheHit), 0.0);
        assert!(Phase::ALL.contains(&Phase::CacheHit));
        assert_eq!(Phase::CacheHit.name(), "cachehit");
    }

    /// The fragment-exchange share of the traffic is split out of the
    /// allreduce phase without changing the total — and the prediction
    /// (which buckets by coefficient, not phase) is unaffected.
    #[test]
    fn projection_splits_exchange_traffic_out_of_allreduce() {
        let mut l = Ledger::new();
        l.comm.words = 1000;
        l.comm.rounds = 60;
        l.comm_exch.words = 300;
        l.comm_exch.rounds = 20;
        let m = MachineProfile::cray_ex();
        let p = m.project(&l);
        let ar = p.phase_secs(Phase::Allreduce);
        let ex = p.phase_secs(Phase::FragmentExchange);
        assert!((ar - (m.beta * 700.0 + m.phi * 40.0)).abs() < 1e-18);
        assert!((ex - (m.beta * 300.0 + m.phi * 20.0)).abs() < 1e-18);
        // Total equals the unsplit charge.
        assert!((ar + ex - (m.beta * 1000.0 + m.phi * 60.0)).abs() < 1e-15);
        let pred = m.predict(&l, 1);
        assert_eq!(pred.bandwidth_secs, m.beta * 1000.0);
        assert_eq!(pred.latency_secs, m.phi * 60.0);
        assert_eq!(Phase::FragmentExchange.name(), "exchange");
        // mem accounting rides the critical path by max.
        let mut a = Ledger::new();
        a.mem_words = 10;
        let mut b = Ledger::new();
        b.mem_words = 25;
        assert_eq!(Ledger::critical_path(&[a, b]).mem_per_rank(), 25);
    }

    #[test]
    fn hybrid_projection_divides_kernel_phase_and_clamps_at_cores() {
        let mut l = Ledger::new();
        l.add_flops(Phase::KernelCompute, 1e9);
        l.add_flops(Phase::Solve, 1e6);
        l.comm.words = 1000;
        let m = MachineProfile::cray_ex();
        let p1 = m.project(&l);
        let p4 = m.project_hybrid(&l, 4);
        assert!(
            (p4.phase_secs(Phase::KernelCompute) - p1.phase_secs(Phase::KernelCompute) / 4.0)
                .abs()
                < 1e-18
        );
        // Only the kernel phase scales.
        assert_eq!(p4.phase_secs(Phase::Solve), p1.phase_secs(Phase::Solve));
        assert_eq!(p4.phase_secs(Phase::Allreduce), p1.phase_secs(Phase::Allreduce));
        // threads = 1 and the degenerate 0 are identity.
        assert_eq!(
            m.project_hybrid(&l, 1).total_secs(),
            p1.total_secs()
        );
        assert_eq!(m.project_hybrid(&l, 0).total_secs(), p1.total_secs());
        // Beyond cores_per_rank the speedup saturates.
        let cap = m.cores_per_rank;
        assert_eq!(
            m.project_hybrid(&l, cap).total_secs(),
            m.project_hybrid(&l, 10 * cap).total_secs()
        );
        assert!(p4.total_secs() < p1.total_secs());
    }

    /// The prediction is the projection regrouped by model coefficient:
    /// totals must agree to rounding, and each term must be the plain
    /// weighted count.
    #[test]
    fn predict_splits_projection_by_coefficient() {
        let mut l = Ledger::new();
        l.add_flops(Phase::KernelCompute, 1e9);
        l.add_flops(Phase::Solve, 1e6);
        l.add_flops(Phase::GradCorr, 3e5);
        l.kernel_calls = 10.0;
        l.kernel_rows = 80.0;
        l.iters = 500.0;
        l.comm.words = 123_456;
        l.comm.rounds = 789;
        let m = MachineProfile::cray_ex();
        for threads in [1usize, 3, 64] {
            let pred = m.predict(&l, threads);
            let proj = m.project_hybrid(&l, threads);
            let (a, b) = (pred.total_secs(), proj.total_secs());
            assert!(
                (a - b).abs() <= 1e-12 * a.max(b),
                "t={threads}: predicted {a} vs projected {b}"
            );
            assert_eq!(pred.bandwidth_secs, m.beta * 123_456.0);
            assert_eq!(pred.latency_secs, m.phi * 789.0);
        }
        // More threads shrink only the compute term.
        let p1 = m.predict(&l, 1);
        let p4 = m.predict(&l, 4);
        assert!(p4.compute_secs < p1.compute_secs);
        assert_eq!(p4.bandwidth_secs, p1.bandwidth_secs);
        assert_eq!(p4.latency_secs, p1.latency_secs);
    }

    /// The overlap term charges `max(posted comm, hidden compute)`
    /// instead of their sum: the projection subtracts the min, capped by
    /// whichever side is smaller, and a blocking ledger (nothing posted)
    /// saves nothing.
    #[test]
    fn overlap_saving_is_min_of_posted_and_hidden() {
        let m = MachineProfile::cray_ex();
        let mut blocking = Ledger::new();
        blocking.add_flops(Phase::KernelCompute, 1e9);
        blocking.comm.words = 1_000_000;
        blocking.comm.rounds = 100;
        let base = m.project(&blocking);
        assert_eq!(base.overlap_saved_secs, 0.0);

        // Comm-bound regime: plenty of hidden compute, the posted wire
        // time is the smaller side — the whole posted share is hidden.
        let mut l = blocking.clone();
        l.comm_posted.words = 10_000;
        l.comm_posted.rounds = 10;
        l.add_hidden_flops(Phase::KernelCompute, 9e8);
        let posted_secs = m.beta * 10_000.0 + m.phi * 10.0;
        let p = m.project(&l);
        assert!((p.overlap_saved_secs - posted_secs).abs() < 1e-15);
        // Per-phase rows keep the blocking charge; only the total drops.
        assert_eq!(p.phase_secs(Phase::Allreduce), base.phase_secs(Phase::Allreduce));
        assert!((base.total_secs() - p.total_secs() - posted_secs).abs() < 1e-15);

        // Compute-bound regime: a sliver of hidden compute under a big
        // posted collective — the saving is capped at the hidden side.
        let mut l2 = blocking.clone();
        l2.comm_posted.words = 900_000;
        l2.comm_posted.rounds = 90;
        l2.add_hidden_flops(Phase::Solve, 1e6);
        let hidden_secs = m.gamma * 1e6;
        let p2 = m.project(&l2);
        assert!((p2.overlap_saved_secs - hidden_secs).abs() < 1e-15);

        // The saving can never exceed either side.
        for p in [&p, &p2] {
            let posted = p.comm; // totals; posted ⊆ totals by contract
            let wire = m.beta * posted.words as f64 + m.phi * posted.rounds as f64;
            assert!(p.overlap_saved_secs <= wire + 1e-15);
        }
    }

    /// Prediction and hybrid projection stay pinned (1e-12 relative)
    /// with the overlap term active, in both regimes, across threads —
    /// and the hidden kernel compute shrinks with the thread split, so
    /// the saving is re-derived per `t`.
    #[test]
    fn predict_matches_projection_with_overlap() {
        let m = MachineProfile::cray_ex();
        let mut l = Ledger::new();
        l.add_flops(Phase::KernelCompute, 1e9);
        l.add_flops(Phase::Solve, 1e6);
        l.kernel_calls = 10.0;
        l.kernel_rows = 80.0;
        l.iters = 500.0;
        l.comm.words = 123_456;
        l.comm.rounds = 789;
        l.comm_posted.words = 60_000;
        l.comm_posted.rounds = 300;
        l.add_hidden_flops(Phase::KernelCompute, 5e8);
        for threads in [1usize, 3, 64] {
            let pred = m.predict(&l, threads);
            let proj = m.project_hybrid(&l, threads);
            let (a, b) = (pred.total_secs(), proj.total_secs());
            assert!(
                (a - b).abs() <= 1e-12 * a.max(b),
                "t={threads}: predicted {a} vs projected {b}"
            );
        }
        // More threads shrink the hidden compute too: at high t the
        // saving can flip from comm-bound to compute-bound.
        let s1 = m.overlap_saved(&l, 1);
        let s16 = m.overlap_saved(&l, 16);
        assert!(s16 <= s1 + 1e-18);
    }

    #[test]
    fn predict_dominant_term_tags() {
        let z = Predicted {
            compute_secs: 1.0,
            bandwidth_secs: 0.5,
            latency_secs: 0.25,
        };
        assert_eq!(z.dominant(), "compute");
        assert_eq!(
            Predicted {
                latency_secs: 2.0,
                ..z
            }
            .dominant(),
            "latency"
        );
        assert_eq!(
            Predicted {
                bandwidth_secs: 2.0,
                ..z
            }
            .dominant(),
            "bandwidth"
        );
    }

    #[test]
    fn machine_parse_named_profiles_and_overrides() {
        let m = MachineProfile::parse("cray-ex").unwrap();
        assert_eq!(m.name, "cray-ex");
        assert_eq!(m.phi, MachineProfile::cray_ex().phi);
        let m = MachineProfile::parse("cloud").unwrap();
        assert_eq!(m.name, "cloud");
        let m =
            MachineProfile::parse("cray-ex:alpha=1e-3,beta=2e-8,gamma=3e-10,cores=32").unwrap();
        assert_eq!(m.phi, 1e-3);
        assert_eq!(m.beta, 2e-8);
        assert_eq!(m.gamma, 3e-10);
        assert_eq!(m.cores_per_rank, 32);
        // Partial overrides keep the base for the rest.
        let m = MachineProfile::parse("cloud:alpha=1.5e-4").unwrap();
        assert_eq!(m.phi, 1.5e-4);
        assert_eq!(m.beta, MachineProfile::cloud().beta);
    }

    /// The strict-parsing satellite: malformed or non-positive
    /// `alpha`/`beta`/`gamma` (and `cores`) values must be hard errors
    /// naming the key, matching the `Config::try_*` convention.
    #[test]
    fn machine_parse_rejects_malformed_and_negative_naming_the_key() {
        for (spec, key) in [
            ("cray-ex:alpha=-1e-6", "'machine.alpha'"),
            ("cray-ex:alpha=0", "'machine.alpha'"),
            ("cray-ex:alpha=fast", "'machine.alpha'"),
            ("cray-ex:alpha=inf", "'machine.alpha'"),
            ("cray-ex:alpha=nan", "'machine.alpha'"),
            ("cloud:beta=-4e-9", "'machine.beta'"),
            ("cloud:beta=", "'machine.beta'"),
            ("cray-ex:gamma=zero", "'machine.gamma'"),
            ("cray-ex:gamma=-2.5e-10", "'machine.gamma'"),
            ("cray-ex:cores=0", "'machine.cores'"),
            ("cray-ex:cores=2.5", "'machine.cores'"),
            ("cray-ex:cores=-4", "'machine.cores'"),
            ("cray-ex:alpha", "'machine'"),
            ("cray-ex:watts=5", "'machine'"),
            ("laptop", "'machine'"),
        ] {
            let err = MachineProfile::parse(spec).expect_err(spec);
            assert!(err.contains(key), "{spec}: error must name {key}, got: {err}");
        }
    }

    #[test]
    fn balance_point_is_sane() {
        // Latency should dominate messages smaller than ~1000 words on the
        // Cray-EX-like profile (the regime where s-step wins big).
        let m = MachineProfile::cray_ex();
        assert!(m.balance_words() > 100.0);
        assert!(m.balance_words() < 100_000.0);
        // The cloud profile is far more latency-dominated.
        assert!(MachineProfile::cloud().balance_words() > m.balance_words());
    }

    /// Every field — including coefficients with no short decimal form —
    /// survives a serialize → parse cycle bit for bit (`{:e}` prints
    /// the shortest representation that round-trips through
    /// `str::parse::<f64>`).
    #[test]
    fn profile_roundtrip_is_bitwise() {
        let p = MachineProfile {
            name: "calibrated",
            gamma: 2.5e-10 * (1.0 + f64::EPSILON),
            beta: 1.0 / 3.0 * 1e-8,
            phi: 5.000000000000001e-6,
            mu_scale: 1.7,
            blas1_penalty: 3.9999999999999996,
            iter_overhead: 4.9e-6,
            cores_per_rank: 48,
        };
        let q = MachineProfile::from_profile_string(&p.to_profile_string())
            .expect("own output must parse");
        assert_eq!(p.name, q.name);
        assert_eq!(p.gamma.to_bits(), q.gamma.to_bits());
        assert_eq!(p.beta.to_bits(), q.beta.to_bits());
        assert_eq!(p.phi.to_bits(), q.phi.to_bits());
        assert_eq!(p.mu_scale.to_bits(), q.mu_scale.to_bits());
        assert_eq!(p.blas1_penalty.to_bits(), q.blas1_penalty.to_bits());
        assert_eq!(p.iter_overhead.to_bits(), q.iter_overhead.to_bits());
        assert_eq!(p.cores_per_rank, q.cores_per_rank);
    }

    /// `save` → `parse("profile:<path>")` is the full CLI loop: the file
    /// written by `--calibrate` is what `--machine profile:` consumes.
    #[test]
    fn profile_save_load_through_machine_spec() {
        let dir = std::env::temp_dir().join("kcd_costmodel_profile");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.toml");
        let mut p = MachineProfile::cloud();
        p.name = "calibrated";
        p.gamma = 3.141592653589793e-10;
        p.save(&path).expect("save");
        let spec = format!("profile:{}", path.display());
        let q = MachineProfile::parse(&spec).expect("load through parse");
        std::fs::remove_file(&path).ok();
        assert_eq!(q.name, "calibrated");
        assert_eq!(q.gamma.to_bits(), p.gamma.to_bits());
        assert_eq!(q.beta.to_bits(), p.beta.to_bits());
        assert_eq!(q.phi.to_bits(), p.phi.to_bits());
        assert_eq!(q.cores_per_rank, p.cores_per_rank);
    }

    /// Known name tags map back to their static names; anything else is
    /// `calibrated`.
    #[test]
    fn profile_name_tags_map_to_static_names() {
        for (tag, want) in [
            ("cray-ex", "cray-ex"),
            ("cloud", "cloud"),
            ("my-workstation", "calibrated"),
        ] {
            let text = format!(
                "profile = \"{tag}\"\nalpha = 1e-6\nbeta = 1e-9\ngamma = 1e-10\ncores = 4\n"
            );
            let p = MachineProfile::from_profile_string(&text).expect(tag);
            assert_eq!(p.name, want);
        }
        // Absent tag defaults to calibrated, absent shape params to cray-ex's.
        let p = MachineProfile::from_profile_string(
            "alpha = 1e-6\nbeta = 1e-9\ngamma = 1e-10\ncores = 4\n",
        )
        .unwrap();
        assert_eq!(p.name, "calibrated");
        assert_eq!(p.blas1_penalty, MachineProfile::cray_ex().blas1_penalty);
    }

    /// The strict-accessor convention: missing required keys and
    /// malformed or non-positive values are hard errors naming the key.
    #[test]
    fn profile_file_errors_name_the_key() {
        let base = "alpha = 1e-6\nbeta = 1e-9\ngamma = 1e-10\ncores = 4\n";
        for (text, key) in [
            ("beta = 1e-9\ngamma = 1e-10\ncores = 4\n", "alpha"),
            ("alpha = 1e-6\ngamma = 1e-10\ncores = 4\n", "beta"),
            ("alpha = 1e-6\nbeta = 1e-9\ncores = 4\n", "gamma"),
            ("alpha = 1e-6\nbeta = 1e-9\ngamma = 1e-10\n", "cores"),
            ("alpha = -1e-6\nbeta = 1e-9\ngamma = 1e-10\ncores = 4\n", "alpha"),
            ("alpha = \"fast\"\nbeta = 1e-9\ngamma = 1e-10\ncores = 4\n", "alpha"),
            ("alpha = 1e-6\nbeta = 1e-9\ngamma = 1e-10\ncores = 0\n", "cores"),
        ] {
            let err = MachineProfile::from_profile_string(text).expect_err(text);
            assert!(err.contains(key), "{text:?}: error must name {key}, got: {err}");
        }
        // mu-scale is optional, but present-and-broken is still an error.
        let text = format!("{base}mu-scale = 0\n");
        let err = MachineProfile::from_profile_string(&text).unwrap_err();
        assert!(err.contains("mu-scale"), "{err}");
        // A missing file through the machine spec names the path.
        let err = MachineProfile::parse("profile:/nonexistent/kcd.toml").unwrap_err();
        assert!(err.contains("/nonexistent/kcd.toml"), "{err}");
    }
}
