//! Closed-form leading-order costs of Theorems 1 and 2 (and their `b = 1`
//! DCD specializations), used to cross-check measured counts and to
//! reason about the bandwidth–latency–computation trade-off analytically.

/// Problem dimensions for the cost formulas.
#[derive(Clone, Copy, Debug)]
pub struct ProblemDims {
    /// Number of samples (kernel-matrix dimension).
    pub m: usize,
    /// Number of features.
    pub n: usize,
    /// Matrix density `f ∈ (0, 1]`.
    pub f: f64,
    /// Nonlinear kernel-map cost scalar `µ` (flop-equivalents per entry).
    pub mu: f64,
    /// Number of processors (divides the per-iteration compute).
    pub p: usize,
    /// Participant count of the per-iteration reduce collective — the
    /// Hockney latency term is `O(log₂ reduce_ranks)`, **not**
    /// `O(log₂ p)`: for the 1D layout the two coincide (`reduce_ranks =
    /// p`), but a 2D `pr × pc` grid reduces over a `pc`-rank
    /// subcommunicator, so its projected latency must use `pc`. (The
    /// projection used to hard-code global `p` here, which overstated
    /// grid latency by `log₂ pr` per iteration.)
    pub reduce_ranks: usize,
    /// Total iterations `H` (inner-iteration equivalents).
    pub h: usize,
}

impl ProblemDims {
    /// 1D-layout dimensions: the reduce collective spans all `p` ranks.
    pub fn one_d(m: usize, n: usize, f: f64, mu: f64, p: usize, h: usize) -> ProblemDims {
        ProblemDims {
            m,
            n,
            f,
            mu,
            p,
            reduce_ranks: p,
            h,
        }
    }
}

/// Leading-order algorithm costs along the critical path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AlgoCost {
    /// Flops (γ multiplier).
    pub flops: f64,
    /// Words moved (β multiplier).
    pub words: f64,
    /// Messages / latency rounds (φ multiplier).
    pub msgs: f64,
    /// Words of memory per processor.
    pub storage: f64,
}

impl AlgoCost {
    /// Hockney time under `(γ, β, φ)`.
    pub fn time(&self, gamma: f64, beta: f64, phi: f64) -> f64 {
        gamma * self.flops + beta * self.words + phi * self.msgs
    }
}

/// Theorem 1: BDCD for K-RR with block size `b`.
///
/// Computation `O(H(bfmn/P + µbm + b³ + bm))`, bandwidth `O(Hbm)`,
/// latency `O(H log P)`, storage `O(fmn/P + bm)`.
pub fn bdcd_cost(d: &ProblemDims, b: usize) -> AlgoCost {
    let (m, n, f, mu, p) = (d.m as f64, d.n as f64, d.f, d.mu, d.p as f64);
    let r = d.reduce_ranks as f64;
    let h = d.h as f64;
    let b = b as f64;
    let per_iter_flops = b * f * m * n / p      // partial kernel block
        + mu * b * m                            // nonlinear map
        + b * m                                 // rhs matvecs
        + b * b * b;                            // b×b solve
    AlgoCost {
        flops: h * per_iter_flops,
        words: h * b * m,
        msgs: h * (r.log2().ceil().max(1.0)),
        storage: f * m * n / p + b * m,
    }
}

/// Theorem 2: s-step BDCD for K-RR.
///
/// Computation `O(H/s (sbfmn/P + µsbm + sb³ + C(s,2)b² + sbm))`, bandwidth
/// `O(H/s · sbm)` (same total words), latency `O(H/s log P)`, storage
/// `O(fmn/P + sbm)`.
pub fn bdcd_sstep_cost(d: &ProblemDims, b: usize, s: usize) -> AlgoCost {
    let (m, n, f, mu, p) = (d.m as f64, d.n as f64, d.f, d.mu, d.p as f64);
    let r = d.reduce_ranks as f64;
    let outer = (d.h as f64 / s as f64).ceil();
    let b = b as f64;
    let s = s as f64;
    let per_outer_flops = s * b * f * m * n / p
        + mu * s * b * m
        + s * b * m
        + s * b * b * b
        + s * (s - 1.0) / 2.0 * b * b; // C(s,2) b² gradient corrections
    AlgoCost {
        flops: outer * per_outer_flops,
        words: outer * s * b * m,
        msgs: outer * (r.log2().ceil().max(1.0)),
        storage: f * m * n / p + s * b * m,
    }
}

/// DCD for K-SVM = Theorem 1 specialized to `b = 1` (no `b³` solve; the
/// scalar subproblem is O(1)).
pub fn dcd_cost(d: &ProblemDims) -> AlgoCost {
    bdcd_cost(d, 1)
}

/// s-step DCD for K-SVM = Theorem 2 specialized to `b = 1`.
pub fn dcd_sstep_cost(d: &ProblemDims, s: usize) -> AlgoCost {
    bdcd_sstep_cost(d, 1, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ProblemDims {
        ProblemDims::one_d(10_000, 100_000, 0.01, 30.0, 256, 1024)
    }

    #[test]
    fn sstep_reduces_latency_by_s() {
        let d = dims();
        let base = dcd_cost(&d);
        for s in [2, 8, 64] {
            let ss = dcd_sstep_cost(&d, s);
            assert!(
                (ss.msgs - base.msgs / s as f64).abs() / base.msgs < 1e-9,
                "latency should drop by s"
            );
        }
    }

    /// The latency term must follow the reduce collective's participant
    /// count, not the global processor count: a pr×pc grid reduce over a
    /// pc-rank subcommunicator costs log₂ pc rounds per iteration, and
    /// 1D costs (reduce_ranks = p) are unchanged.
    #[test]
    fn latency_uses_reduce_participants_not_global_p() {
        let one_d = dims();
        let grid = ProblemDims {
            reduce_ranks: 16, // pr = 16, pc = 16 over the same 256 ranks
            ..one_d
        };
        let c1 = bdcd_cost(&one_d, 4);
        let cg = bdcd_cost(&grid, 4);
        // Same compute and bandwidth; latency halves (log2 256 → log2 16).
        assert_eq!(cg.flops, c1.flops);
        assert_eq!(cg.words, c1.words);
        assert!((cg.msgs - c1.msgs / 2.0).abs() < 1e-9, "{} vs {}", cg.msgs, c1.msgs);
        let s1 = bdcd_sstep_cost(&one_d, 4, 16);
        let sg = bdcd_sstep_cost(&grid, 4, 16);
        assert!((sg.msgs - s1.msgs / 2.0).abs() < 1e-9);
    }

    #[test]
    fn sstep_preserves_total_bandwidth() {
        let d = dims();
        let base = bdcd_cost(&d, 4);
        let ss = bdcd_sstep_cost(&d, 4, 16);
        // The paper's key contrast with prior s-step CD: total words are
        // unchanged (per-message size grows by s instead).
        assert!((ss.words - base.words).abs() / base.words < 1e-9);
    }

    #[test]
    fn sstep_adds_gradient_correction_flops() {
        let d = dims();
        let base = bdcd_cost(&d, 2);
        let ss = bdcd_sstep_cost(&d, 2, 32);
        assert!(ss.flops > base.flops);
        // The extra work is the C(s,2) b² term per outer iteration.
        let outer = (d.h as f64 / 32.0).ceil();
        let extra = outer * 32.0 * 31.0 / 2.0 * 4.0;
        assert!((ss.flops - base.flops - extra).abs() / base.flops < 1e-9);
    }

    #[test]
    fn sstep_storage_grows_with_s() {
        let d = dims();
        let base = bdcd_cost(&d, 1);
        let ss = bdcd_sstep_cost(&d, 1, 256);
        assert!(ss.storage > base.storage);
        assert!((ss.storage - base.storage - 255.0 * d.m as f64).abs() < 1.0);
    }

    #[test]
    fn latency_dominated_regime_prefers_sstep() {
        // duke-like: tiny m, large n — the paper's 9.8× case.
        let d = ProblemDims::one_d(44, 7129, 1.0, 30.0, 512, 4096);
        let (g, b, ph) = (2.5e-10, 4.0e-9, 5.0e-6);
        let t_base = dcd_cost(&d).time(g, b, ph);
        let t_sstep = dcd_sstep_cost(&d, 32).time(g, b, ph);
        let speedup = t_base / t_sstep;
        assert!(
            speedup > 4.0 && speedup < 40.0,
            "expected paper-like speedup regime, got {speedup}"
        );
    }

    #[test]
    fn bandwidth_dominated_regime_caps_sstep_gain() {
        // news20-like K-RR with b=4: m is large, so the bm-word messages
        // are bandwidth-bound and the s-step win collapses (~1.1× in the
        // paper).
        let d = ProblemDims::one_d(19_996, 1_355_191, 0.0003, 30.0, 2048, 1024);
        let (g, b, ph) = (2.5e-10, 4.0e-9, 5.0e-6);
        let t_base = bdcd_cost(&d, 4).time(g, b, ph);
        let t_sstep = bdcd_sstep_cost(&d, 4, 64).time(g, b, ph);
        let speedup = t_base / t_sstep;
        assert!(
            speedup < 2.0,
            "bandwidth-bound regime should cap the win, got {speedup}"
        );
        assert!(speedup > 0.9, "s-step should not lose badly, got {speedup}");
    }
}
