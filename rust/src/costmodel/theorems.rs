//! Closed-form leading-order costs of Theorems 1 and 2 (and their `b = 1`
//! DCD specializations), used to cross-check measured counts and to
//! reason about the bandwidth–latency–computation trade-off analytically.

/// Problem dimensions for the cost formulas.
#[derive(Clone, Copy, Debug)]
pub struct ProblemDims {
    /// Number of samples (kernel-matrix dimension).
    pub m: usize,
    /// Number of features.
    pub n: usize,
    /// Matrix density `f ∈ (0, 1]`.
    pub f: f64,
    /// Nonlinear kernel-map cost scalar `µ` (flop-equivalents per entry).
    pub mu: f64,
    /// Number of processors.
    pub p: usize,
    /// Total iterations `H` (inner-iteration equivalents).
    pub h: usize,
}

/// Leading-order algorithm costs along the critical path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AlgoCost {
    /// Flops (γ multiplier).
    pub flops: f64,
    /// Words moved (β multiplier).
    pub words: f64,
    /// Messages / latency rounds (φ multiplier).
    pub msgs: f64,
    /// Words of memory per processor.
    pub storage: f64,
}

impl AlgoCost {
    /// Hockney time under `(γ, β, φ)`.
    pub fn time(&self, gamma: f64, beta: f64, phi: f64) -> f64 {
        gamma * self.flops + beta * self.words + phi * self.msgs
    }
}

/// Theorem 1: BDCD for K-RR with block size `b`.
///
/// Computation `O(H(bfmn/P + µbm + b³ + bm))`, bandwidth `O(Hbm)`,
/// latency `O(H log P)`, storage `O(fmn/P + bm)`.
pub fn bdcd_cost(d: &ProblemDims, b: usize) -> AlgoCost {
    let (m, n, f, mu, p) = (d.m as f64, d.n as f64, d.f, d.mu, d.p as f64);
    let h = d.h as f64;
    let b = b as f64;
    let per_iter_flops = b * f * m * n / p      // partial kernel block
        + mu * b * m                            // nonlinear map
        + b * m                                 // rhs matvecs
        + b * b * b;                            // b×b solve
    AlgoCost {
        flops: h * per_iter_flops,
        words: h * b * m,
        msgs: h * (p.log2().ceil().max(1.0)),
        storage: f * m * n / p + b * m,
    }
}

/// Theorem 2: s-step BDCD for K-RR.
///
/// Computation `O(H/s (sbfmn/P + µsbm + sb³ + C(s,2)b² + sbm))`, bandwidth
/// `O(H/s · sbm)` (same total words), latency `O(H/s log P)`, storage
/// `O(fmn/P + sbm)`.
pub fn bdcd_sstep_cost(d: &ProblemDims, b: usize, s: usize) -> AlgoCost {
    let (m, n, f, mu, p) = (d.m as f64, d.n as f64, d.f, d.mu, d.p as f64);
    let outer = (d.h as f64 / s as f64).ceil();
    let b = b as f64;
    let s = s as f64;
    let per_outer_flops = s * b * f * m * n / p
        + mu * s * b * m
        + s * b * m
        + s * b * b * b
        + s * (s - 1.0) / 2.0 * b * b; // C(s,2) b² gradient corrections
    AlgoCost {
        flops: outer * per_outer_flops,
        words: outer * s * b * m,
        msgs: outer * (p.log2().ceil().max(1.0)),
        storage: f * m * n / p + s * b * m,
    }
}

/// DCD for K-SVM = Theorem 1 specialized to `b = 1` (no `b³` solve; the
/// scalar subproblem is O(1)).
pub fn dcd_cost(d: &ProblemDims) -> AlgoCost {
    bdcd_cost(d, 1)
}

/// s-step DCD for K-SVM = Theorem 2 specialized to `b = 1`.
pub fn dcd_sstep_cost(d: &ProblemDims, s: usize) -> AlgoCost {
    bdcd_sstep_cost(d, 1, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ProblemDims {
        ProblemDims {
            m: 10_000,
            n: 100_000,
            f: 0.01,
            mu: 30.0,
            p: 256,
            h: 1024,
        }
    }

    #[test]
    fn sstep_reduces_latency_by_s() {
        let d = dims();
        let base = dcd_cost(&d);
        for s in [2, 8, 64] {
            let ss = dcd_sstep_cost(&d, s);
            assert!(
                (ss.msgs - base.msgs / s as f64).abs() / base.msgs < 1e-9,
                "latency should drop by s"
            );
        }
    }

    #[test]
    fn sstep_preserves_total_bandwidth() {
        let d = dims();
        let base = bdcd_cost(&d, 4);
        let ss = bdcd_sstep_cost(&d, 4, 16);
        // The paper's key contrast with prior s-step CD: total words are
        // unchanged (per-message size grows by s instead).
        assert!((ss.words - base.words).abs() / base.words < 1e-9);
    }

    #[test]
    fn sstep_adds_gradient_correction_flops() {
        let d = dims();
        let base = bdcd_cost(&d, 2);
        let ss = bdcd_sstep_cost(&d, 2, 32);
        assert!(ss.flops > base.flops);
        // The extra work is the C(s,2) b² term per outer iteration.
        let outer = (d.h as f64 / 32.0).ceil();
        let extra = outer * 32.0 * 31.0 / 2.0 * 4.0;
        assert!((ss.flops - base.flops - extra).abs() / base.flops < 1e-9);
    }

    #[test]
    fn sstep_storage_grows_with_s() {
        let d = dims();
        let base = bdcd_cost(&d, 1);
        let ss = bdcd_sstep_cost(&d, 1, 256);
        assert!(ss.storage > base.storage);
        assert!((ss.storage - base.storage - 255.0 * d.m as f64).abs() < 1.0);
    }

    #[test]
    fn latency_dominated_regime_prefers_sstep() {
        // duke-like: tiny m, large n — the paper's 9.8× case.
        let d = ProblemDims {
            m: 44,
            n: 7129,
            f: 1.0,
            mu: 30.0,
            p: 512,
            h: 4096,
        };
        let (g, b, ph) = (2.5e-10, 4.0e-9, 5.0e-6);
        let t_base = dcd_cost(&d).time(g, b, ph);
        let t_sstep = dcd_sstep_cost(&d, 32).time(g, b, ph);
        let speedup = t_base / t_sstep;
        assert!(
            speedup > 4.0 && speedup < 40.0,
            "expected paper-like speedup regime, got {speedup}"
        );
    }

    #[test]
    fn bandwidth_dominated_regime_caps_sstep_gain() {
        // news20-like K-RR with b=4: m is large, so the bm-word messages
        // are bandwidth-bound and the s-step win collapses (~1.1× in the
        // paper).
        let d = ProblemDims {
            m: 19_996,
            n: 1_355_191,
            f: 0.0003,
            mu: 30.0,
            p: 2048,
            h: 1024,
        };
        let (g, b, ph) = (2.5e-10, 4.0e-9, 5.0e-6);
        let t_base = bdcd_cost(&d, 4).time(g, b, ph);
        let t_sstep = bdcd_sstep_cost(&d, 4, 64).time(g, b, ph);
        let speedup = t_base / t_sstep;
        assert!(
            speedup < 2.0,
            "bandwidth-bound regime should cap the win, got {speedup}"
        );
        assert!(speedup > 0.9, "s-step should not lose badly, got {speedup}");
    }
}
