//! Model serving: batched prediction through the gram engine, plus the
//! request parsing behind `kcd serve` / `kcd predict`.
//!
//! A query batch against a trained kernel model *is* a sampled-row gram
//! product: `f(x_r) = Σ_i coef_i · K(x_r, a_i)` needs the cross-set
//! kernel block `K(X_S, A)` — the same shape the training solvers pull
//! from [`crate::gram::GramEngine`] every iteration. Serving therefore
//! reuses the whole engine stack instead of growing a second kernel
//! path:
//!
//! * [`ServeProduct`] is a [`ProductStage`] over the *query* rows whose
//!   `m` is the retained-training-row count: `compute(sample, q)` fills
//!   `q[r][i] = K(x_{sample_r}, a_i)` (a finished-kernel block,
//!   [`BlockKind::Kernel`] — the kernel map runs inside the product via
//!   [`Kernel::apply_packed`], the cross-set twin of the training
//!   epilogue).
//! * [`crate::parallel::ParallelProduct`] splits a batch's rows across
//!   worker threads exactly as in training — bitwise-invariant in the
//!   thread count.
//! * The engine's kernel-row LRU cache keys on *query indices*: a
//!   skewed or repeat-heavy request stream (the regime where serving
//!   cost is dominated by kernel evaluation against stored training
//!   rows) turns repeats into row copies that skip the product
//!   entirely, with hits attributed to
//!   [`crate::costmodel::Phase::CacheHit`] as in training.
//!
//! ### Determinism contract
//!
//! Predictions are **bitwise identical** to the naive reference
//! evaluation ([`crate::model::SvmModel::decision_function`] /
//! [`crate::model::KrrModel::predict`]) and **bitwise invariant** to
//! the worker-thread count, the cache capacity (including off), and how
//! the request stream is split into batches. The proof obligations are
//! the same three the training contract rests on: every product path
//! sums each output entry in ascending stored-column order (identical
//! to [`Csr::row_dot`]'s merge join), [`Kernel::apply_packed`] is
//! elementwise identical to [`Kernel::apply_scalar`], and cached rows
//! are verbatim copies of computed rows. `rust/tests/serve_props.rs`
//! pins all three; `tools/detlint` checks this module's preconditions
//! statically (`serve` is a deterministic-core module: no map-order
//! dependence, no ambient clocks — wall-clock serving counters live in
//! the CLI layer via `util::PhaseTimer`).
//!
//! Model persistence (the `.kcd` format) lives in [`format`]; the
//! sharded-grid extraction helpers ([`format::shard_cells`] /
//! [`format::assemble_cells`]) reassemble training rows from
//! `GridStorage::Sharded` cells through the same `pack_rows` /
//! `from_packed` kernels the save path serializes with.

#![forbid(unsafe_code)]

pub mod format;

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use crate::costmodel::Ledger;
use crate::dense::Mat;
use crate::gram::{
    BlockKind, GramEngine, Layout, NoReduce, ProductCost, ProductStage,
    TRANSPOSE_GRAM_MAX_DENSITY,
};
use crate::kernelfn::Kernel;
use crate::model::{KrrModel, SvmModel};
use crate::parallel::ParallelProduct;
use crate::sparse::Csr;

use format::ModelKind;

/// Cross-set kernel product: `q[r][i] = K(x_{sample_r}, a_i)` for query
/// rows `x` against retained training rows `a`. A [`ProductStage`] whose
/// sample space is the *query* set while `m` is the training-row count —
/// which is exactly what lets [`GramEngine`]'s row cache key on query
/// indices. Emits finished kernel values ([`BlockKind::Kernel`]); the
/// kernel map runs inside `compute` so every engine configuration
/// (cached, threaded) sees the same bits.
///
/// `Clone` replicates the stage per worker thread: the matrices and norm
/// vectors are `Arc`-shared, so a clone costs refcounts plus an empty
/// scratch buffer.
#[derive(Clone)]
pub struct ServeProduct {
    queries: Arc<Csr>,
    train: Arc<Csr>,
    /// Cached transpose of `train` for the sparse path (None for dense
    /// training data) — the same density crossover as training's
    /// `CsrProduct`.
    train_t: Option<Arc<Csr>>,
    q_norms: Arc<Vec<f64>>,
    t_norms: Arc<Vec<f64>>,
    kernel: Kernel,
    /// Dense gathered-query scratch for the blocked path (private per
    /// clone — the only `&mut` state).
    scratch: Vec<f64>,
}

impl ServeProduct {
    /// Wrap a query set against retained training rows. Panics on a
    /// feature-dimension mismatch (the model API layers report that as a
    /// load/validation error before construction).
    pub fn new(queries: Arc<Csr>, train: Arc<Csr>, kernel: Kernel) -> ServeProduct {
        assert_eq!(
            queries.ncols(),
            train.ncols(),
            "feature dimension mismatch: queries {} vs model {}",
            queries.ncols(),
            train.ncols()
        );
        let train_t =
            (train.density() < TRANSPOSE_GRAM_MAX_DENSITY).then(|| Arc::new(train.transpose()));
        let q_norms = Arc::new(queries.row_norms_sq());
        let t_norms = Arc::new(train.row_norms_sq());
        ServeProduct {
            queries,
            train,
            train_t,
            q_norms,
            t_norms,
            kernel,
            scratch: Vec::new(),
        }
    }

    /// `K(a_i, a_i)` over the retained training rows (the engine's diag).
    pub fn train_diag(&self) -> Vec<f64> {
        self.t_norms
            .iter()
            .map(|&n| self.kernel.apply_scalar(n, n, n))
            .collect()
    }
}

impl ProductStage for ServeProduct {
    fn m(&self) -> usize {
        self.train.nrows()
    }

    fn kind(&self) -> BlockKind {
        BlockKind::Kernel
    }

    fn compute(&mut self, sample: &[usize], q: &mut Mat) -> ProductCost {
        match &self.train_t {
            Some(tt) => self.queries.sampled_gram_t_against(tt.as_ref(), sample, q),
            None => {
                self.queries
                    .sampled_gram_blocked_against(sample, &self.train, q, &mut self.scratch);
            }
        }
        // The cross-set epilogue: elementwise identical to
        // `apply_scalar(dot, ‖x_r‖², ‖a_i‖²)` over the k × m block.
        let sample_norms: Vec<f64> = sample.iter().map(|&r| self.q_norms[r]).collect();
        self.kernel
            .apply_packed(q.data_mut(), &sample_norms, &self.t_norms);
        let k = sample.len();
        ProductCost {
            flops: 2.0 * k as f64 * self.train.nnz() as f64
                + self.kernel.epilogue_flops(k, self.train.nrows()),
            rows_charged: k,
        }
    }
}

/// Engine-routed prediction knobs. All three are pure wall-time knobs:
/// results are bitwise identical for every combination (pinned by
/// `rust/tests/serve_props.rs`).
#[derive(Clone, Copy, Debug)]
pub struct PredictOptions {
    /// Worker threads for the batch product (≥ 1).
    pub threads: usize,
    /// Kernel-row LRU capacity, keyed on query indices (0 = off).
    pub cache_rows: usize,
    /// Requests per engine call (0 = the whole stream in one batch).
    pub batch: usize,
}

impl Default for PredictOptions {
    fn default() -> Self {
        PredictOptions {
            threads: 1,
            cache_rows: 0,
            batch: 0,
        }
    }
}

/// A prediction session over one query set: the gram engine configured
/// for serving ([`ServeProduct`] + `ParallelProduct` + row cache), plus
/// the model's coefficient vector. Reused across batches so the cache
/// carries hits between them.
pub struct Predictor {
    /// None exactly when the model retained zero rows (an all-zero-α
    /// K-SVM save): the engine would be a `k × 0` pipeline, so predict
    /// short-circuits to zeros instead of building one.
    engine: Option<GramEngine<ParallelProduct<ServeProduct>, NoReduce>>,
    coef: Arc<Vec<f64>>,
    m: usize,
}

impl Predictor {
    /// Build a session for `queries` against a model's retained rows.
    pub fn new(
        train: &Csr,
        coef: &[f64],
        kernel: Kernel,
        queries: &Csr,
        opts: &PredictOptions,
    ) -> Predictor {
        assert_eq!(coef.len(), train.nrows(), "one coefficient per row");
        assert!(opts.threads >= 1, "need at least one worker thread");
        let m = train.nrows();
        let engine = (m > 0).then(|| {
            let product = ServeProduct::new(
                Arc::new(queries.clone()),
                Arc::new(train.clone()),
                kernel,
            );
            let diag = product.train_diag();
            GramEngine::new(
                Layout::Full,
                ParallelProduct::new(product, opts.threads),
                NoReduce,
                None,
                diag,
                opts.cache_rows,
            )
        });
        Predictor {
            engine,
            coef: Arc::new(coef.to_vec()),
            m,
        }
    }

    /// Retained-training-row count (`0` for an empty model).
    pub fn m(&self) -> usize {
        self.m
    }

    /// Score one batch of query indices: `out[r] = Σ_i coef_i ·
    /// K(x_{sample_r}, a_i)`, summed in ascending retained-row order —
    /// the exact summation of the naive reference evaluation.
    pub fn predict_indices(&mut self, sample: &[usize], ledger: &mut Ledger) -> Vec<f64> {
        let Some(engine) = self.engine.as_mut() else {
            // Empty model: the decision sum has no terms.
            return vec![0.0; sample.len()];
        };
        if sample.is_empty() {
            return Vec::new();
        }
        let mut q = Mat::zeros(sample.len(), self.m);
        engine.gram(sample, &mut q, ledger);
        let coef = &self.coef;
        (0..sample.len())
            .map(|r| {
                let mut f = 0.0;
                for (c, v) in coef.iter().zip(q.row(r)) {
                    f += c * v;
                }
                f
            })
            .collect()
    }

    /// Score a request stream in batches of `batch` indices (0 = one
    /// batch). The split is invisible in the bits: every output row is
    /// computed independently, and the cache serves verbatim copies.
    pub fn predict_stream(
        &mut self,
        stream: &[usize],
        batch: usize,
        ledger: &mut Ledger,
    ) -> Vec<f64> {
        let step = if batch == 0 { stream.len().max(1) } else { batch };
        let mut out = Vec::with_capacity(stream.len());
        for chunk in stream.chunks(step) {
            out.extend(self.predict_indices(chunk, ledger));
        }
        out
    }
}

/// A parsed request stream: the deduplicated query matrix plus the
/// per-request row stream into it. Duplicate request lines map to one
/// query row, so the engine's within-batch dedup and the cross-batch LRU
/// cache both see real repeats.
#[derive(Clone, Debug)]
pub struct RequestSet {
    /// Unique query rows, in first-appearance order.
    pub queries: Csr,
    /// One entry per request line: its row in [`RequestSet::queries`].
    pub stream: Vec<usize>,
}

impl RequestSet {
    /// Total request count (duplicates included).
    pub fn len(&self) -> usize {
        self.stream.len()
    }

    /// True when the stream holds no requests.
    pub fn is_empty(&self) -> bool {
        self.stream.is_empty()
    }

    /// Distinct query-row count.
    pub fn unique(&self) -> usize {
        self.queries.nrows()
    }
}

/// Parse one request line: an optional leading label token (any token
/// without `:`, ignored for scoring) followed by 1-based,
/// strictly-ascending `index:value` pairs — the LIBSVM feature syntax,
/// checked against the model's feature dimension. Returns 0-based
/// `(column, value)` pairs.
pub fn parse_request_line(line: &str, line_no: usize, ncols: usize) -> Result<Vec<(usize, f64)>> {
    let mut feats: Vec<(usize, f64)> = Vec::new();
    for (pos, tok) in line.split_whitespace().enumerate() {
        let Some((idx, val)) = tok.split_once(':') else {
            ensure!(
                pos == 0,
                "request line {line_no}: expected index:value, got '{tok}'"
            );
            // Leading label token (echoed convention from LIBSVM files).
            continue;
        };
        let idx: usize = idx.parse().map_err(|_| {
            anyhow!("request line {line_no}: bad feature index in '{tok}'")
        })?;
        ensure!(
            idx >= 1,
            "request line {line_no}: feature indices are 1-based, got {idx}"
        );
        ensure!(
            idx <= ncols,
            "request line {line_no}: feature index {idx} exceeds the \
             model's {ncols} features"
        );
        let val: f64 = val.parse().map_err(|_| {
            anyhow!("request line {line_no}: bad feature value in '{tok}'")
        })?;
        ensure!(
            val.is_finite(),
            "request line {line_no}: feature value in '{tok}' is not finite"
        );
        if let Some(&(last, _)) = feats.last() {
            ensure!(
                idx - 1 > last,
                "request line {line_no}: feature indices must be strictly \
                 ascending ({} then {idx})",
                last + 1
            );
        }
        feats.push((idx - 1, val));
    }
    Ok(feats)
}

/// Parse a line-delimited request stream into a deduplicated
/// [`RequestSet`]. Blank lines and `#` comments are skipped; any
/// malformed line is a hard error naming its line number. Deduplication
/// keys on the *parsed* feature vector (bit-exact values), so two lines
/// differing only in whitespace or label share a query row.
pub fn parse_requests(text: &str, ncols: usize) -> Result<RequestSet> {
    // BTreeMap: deterministic and never iterated — lookups only.
    let mut seen: BTreeMap<Vec<(usize, u64)>, usize> = BTreeMap::new();
    let mut trips: Vec<(usize, usize, f64)> = Vec::new();
    let mut stream = Vec::new();
    let mut unique = 0usize;
    for (i, line) in text.lines().enumerate() {
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let feats = parse_request_line(trimmed, i + 1, ncols)?;
        let key: Vec<(usize, u64)> = feats.iter().map(|&(j, v)| (j, v.to_bits())).collect();
        let row = *seen.entry(key).or_insert_with(|| {
            for &(j, v) in &feats {
                trips.push((unique, j, v));
            }
            unique += 1;
            unique - 1
        });
        stream.push(row);
    }
    Ok(RequestSet {
        queries: Csr::from_triplets(unique, ncols, &trips),
        stream,
    })
}

/// A model loaded for serving: either estimator behind one scoring
/// interface (both predict `Σ coef_i · K(x, a_i)`; only the response
/// rendering differs).
pub enum LoadedModel {
    /// Kernel SVM classifier.
    Svm(SvmModel),
    /// Kernel ridge regressor.
    Krr(KrrModel),
}

impl LoadedModel {
    /// Load a `.kcd` model file, dispatching on its kind header.
    pub fn load(path: &std::path::Path) -> Result<LoadedModel> {
        let raw = format::read_model(path)?;
        Ok(match raw.kind {
            ModelKind::Svm => LoadedModel::Svm(SvmModel::from_kcd(raw)),
            ModelKind::Krr => LoadedModel::Krr(KrrModel::from_kcd(raw)),
        })
    }

    /// Estimator kind.
    pub fn kind(&self) -> ModelKind {
        match self {
            LoadedModel::Svm(_) => ModelKind::Svm,
            LoadedModel::Krr(_) => ModelKind::Krr,
        }
    }

    /// Feature dimension queries must match.
    pub fn ncols(&self) -> usize {
        match self {
            LoadedModel::Svm(m) => m.support_vectors().ncols(),
            LoadedModel::Krr(m) => m.train_matrix().ncols(),
        }
    }

    /// Retained training rows (support vectors / full training set).
    pub fn nrows(&self) -> usize {
        match self {
            LoadedModel::Svm(m) => m.support_vectors().nrows(),
            LoadedModel::Krr(m) => m.train_matrix().nrows(),
        }
    }

    /// The kernel the model was trained with.
    pub fn kernel(&self) -> Kernel {
        match self {
            LoadedModel::Svm(m) => m.kernel(),
            LoadedModel::Krr(m) => m.kernel(),
        }
    }

    /// Build a prediction session over a query set.
    pub fn predictor(&self, queries: &Csr, opts: &PredictOptions) -> Predictor {
        match self {
            LoadedModel::Svm(m) => {
                Predictor::new(m.support_vectors(), m.coefficients(), m.kernel(), queries, opts)
            }
            LoadedModel::Krr(m) => {
                Predictor::new(m.train_matrix(), m.coefficients(), m.kernel(), queries, opts)
            }
        }
    }

    /// Score a parsed request stream in `opts.batch`-sized batches.
    pub fn score(&self, reqs: &RequestSet, opts: &PredictOptions, ledger: &mut Ledger) -> Vec<f64> {
        let mut p = self.predictor(&reqs.queries, opts);
        p.predict_stream(&reqs.stream, opts.batch, ledger)
    }

    /// Render one response line: `±1 <decision value>` for K-SVM (the
    /// sign convention of [`SvmModel::predict`]), the predicted target
    /// for K-RR.
    pub fn response_line(&self, score: f64) -> String {
        match self {
            LoadedModel::Svm(_) => {
                let label = if score >= 0.0 { "+1" } else { "-1" };
                format!("{label} {score:e}")
            }
            LoadedModel::Krr(_) => format!("{score:e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_dense_classification;

    fn toy() -> (Csr, Vec<f64>) {
        let ds = gen_dense_classification(30, 6, 0.02, 7);
        let coef: Vec<f64> = (0..30).map(|i| (i as f64).sin()).collect();
        (ds.a, coef)
    }

    #[test]
    fn predictor_matches_rowwise_reference() {
        let (train, coef) = toy();
        let queries = gen_dense_classification(12, 6, 0.02, 8).a;
        let kernel = Kernel::paper_rbf();
        // Naive reference: ascending-row scalar sum.
        let qn = queries.row_norms_sq();
        let tn = train.row_norms_sq();
        let reference: Vec<f64> = (0..queries.nrows())
            .map(|r| {
                let mut f = 0.0;
                for (j, &c) in coef.iter().enumerate() {
                    let dot = queries.row_dot(r, &train, j);
                    f += c * kernel.apply_scalar(dot, qn[r], tn[j]);
                }
                f
            })
            .collect();
        let sample: Vec<usize> = (0..queries.nrows()).collect();
        for threads in [1, 3] {
            for cache in [0, 5] {
                let opts = PredictOptions {
                    threads,
                    cache_rows: cache,
                    batch: 0,
                };
                let mut p = Predictor::new(&train, &coef, kernel, &queries, &opts);
                let got = p.predict_indices(&sample, &mut Ledger::new());
                let gb: Vec<u64> = got.iter().map(|v| v.to_bits()).collect();
                let rb: Vec<u64> = reference.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, rb, "threads {threads} cache {cache}");
            }
        }
    }

    #[test]
    fn repeated_queries_hit_the_cache() {
        let (train, coef) = toy();
        let queries = gen_dense_classification(4, 6, 0.02, 9).a;
        let opts = PredictOptions {
            threads: 1,
            cache_rows: 8,
            batch: 2,
        };
        let mut p = Predictor::new(&train, &coef, Kernel::paper_rbf(), &queries, &opts);
        let stream = [0, 1, 0, 1, 2, 0, 3, 2];
        let mut ledger = Ledger::new();
        let out = p.predict_stream(&stream, opts.batch, &mut ledger);
        assert_eq!(out.len(), stream.len());
        // 4 unique rows miss once each; the other 4 positions hit.
        assert_eq!(ledger.cache.misses, 4, "{:?}", ledger.cache);
        assert_eq!(ledger.cache.hits, 4, "{:?}", ledger.cache);
        // Repeats are bitwise copies.
        assert_eq!(out[0].to_bits(), out[2].to_bits());
        assert_eq!(out[1].to_bits(), out[3].to_bits());
    }

    #[test]
    fn request_parsing_dedups_and_validates() {
        let text = "+1 1:0.5 3:1.25\n\n# comment\n-1 1:0.5 3:1.25\n2:7\n";
        let reqs = parse_requests(text, 4).unwrap();
        assert_eq!(reqs.len(), 3);
        assert_eq!(reqs.unique(), 2);
        assert_eq!(reqs.stream, vec![0, 0, 1]);
        assert_eq!(reqs.queries.nrows(), 2);
        assert_eq!(reqs.queries.row_parts(0), (&[0usize, 2][..], &[0.5, 1.25][..]));

        for (bad, what) in [
            ("1:0.5 1:0.6", "ascending"),
            ("0:1.0", "1-based"),
            ("9:1.0", "exceeds"),
            ("1:abc", "bad feature value"),
            ("1:2 x", "index:value"),
            ("y:1 2:0.5", "bad feature index"),
            ("1:inf", "finite"),
        ] {
            let err = parse_requests(bad, 4).unwrap_err().to_string();
            assert!(err.contains("request line 1"), "{bad}: {err}");
            assert!(err.contains(what), "{bad}: {err}");
        }
    }

    #[test]
    fn empty_model_predicts_zeros() {
        let queries = gen_dense_classification(5, 6, 0.02, 10).a;
        let empty = Csr::empty(0, 6);
        let mut p = Predictor::new(&empty, &[], Kernel::paper_rbf(), &queries, &PredictOptions::default());
        let out = p.predict_stream(&[0, 1, 2, 3, 4], 2, &mut Ledger::new());
        assert_eq!(out, vec![0.0; 5]);
    }
}
