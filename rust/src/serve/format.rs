//! The versioned `.kcd` on-disk model format.
//!
//! A trained model is exactly the data the serving path needs: the kernel
//! configuration, the coefficient vector, and the retained training rows
//! (support vectors for K-SVM, the full training set for K-RR). The rows
//! are serialized with the same fragment kernels the sharded grid layout
//! exchanges at runtime — [`crate::sparse::Csr::pack_rows`] writes the
//! `(column, value)` stream and [`crate::sparse::Csr::from_packed`]
//! rebuilds it *bitwise verbatim* — so a save → load round trip cannot
//! perturb a single prediction bit.
//!
//! Layout (all integers and floats little-endian; one flat byte stream):
//!
//! | field     | type        | meaning                                   |
//! |-----------|-------------|-------------------------------------------|
//! | magic     | 8 bytes     | `KCDMODEL`                                |
//! | version   | u32         | format version (currently 1)              |
//! | kind      | u32         | 0 = K-SVM, 1 = K-RR                       |
//! | kernel    | u32         | 0 = linear, 1 = poly, 2 = rbf             |
//! | kparam1   | f64         | poly `c` / rbf `sigma` (0 for linear)     |
//! | kparam2   | f64         | poly degree `d` (0 otherwise)             |
//! | lambda    | f64         | K-RR ridge penalty (0 for K-SVM)          |
//! | rows      | u64         | retained training rows                    |
//! | cols      | u64         | feature dimension                         |
//! | nnz       | u64         | total stored entries                      |
//! | coef      | rows × f64  | `α_i y_i` (K-SVM) / `α_i / λ` (K-RR)      |
//! | row_nnz   | rows × u64  | per-row entry counts (`from_packed` header)|
//! | packed    | 2·nnz × f64 | the `pack_rows` `(column, value)` stream  |
//!
//! Every header inconsistency — truncation, version or kind mismatch,
//! `nnz` vs `row_nnz` disagreement, an out-of-range packed column — is a
//! hard error naming the offending field in the `Config::try_*` style
//! (`invalid value for 'model.<field>': …`), never silent garbage:
//! [`Csr::from_packed`] would *panic* on a malformed stream, so the
//! reader re-validates every promise before handing bytes to it.

use anyhow::{anyhow, bail, ensure, Result};

use crate::kernelfn::Kernel;
use crate::sparse::Csr;

/// Magic prefix of every `.kcd` model file.
pub const MAGIC: &[u8; 8] = b"KCDMODEL";

/// Current (and only) format version.
pub const VERSION: u32 = 1;

/// Which estimator a model file holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// Kernel SVM classifier (support vectors + `α_i y_i`).
    Svm,
    /// Kernel ridge regressor (all training rows + `α_i / λ`).
    Krr,
}

impl ModelKind {
    /// Report / error-message name.
    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Svm => "svm",
            ModelKind::Krr => "krr",
        }
    }

    fn tag(self) -> u32 {
        match self {
            ModelKind::Svm => 0,
            ModelKind::Krr => 1,
        }
    }

    fn from_tag(tag: u32) -> Option<ModelKind> {
        match tag {
            0 => Some(ModelKind::Svm),
            1 => Some(ModelKind::Krr),
            _ => None,
        }
    }
}

/// A decoded model file: everything [`write_model`] persisted, validated.
#[derive(Clone, Debug)]
pub struct RawModel {
    /// Estimator kind.
    pub kind: ModelKind,
    /// Kernel configuration.
    pub kernel: Kernel,
    /// K-RR ridge penalty (0.0 in K-SVM files).
    pub lambda: f64,
    /// Retained training rows (bitwise identical to what was saved).
    pub mat: Csr,
    /// Per-row prediction coefficients.
    pub coef: Vec<f64>,
}

fn kernel_tags(k: Kernel) -> (u32, f64, f64) {
    match k {
        Kernel::Linear => (0, 0.0, 0.0),
        Kernel::Poly { c, d } => (1, c, f64::from(d)),
        Kernel::Rbf { sigma } => (2, sigma, 0.0),
    }
}

/// Serialize a model to the `.kcd` byte stream.
pub fn model_bytes(kind: ModelKind, kernel: Kernel, lambda: f64, mat: &Csr, coef: &[f64]) -> Vec<u8> {
    assert_eq!(coef.len(), mat.nrows(), "one coefficient per retained row");
    let rows: Vec<usize> = (0..mat.nrows()).collect();
    let packed = mat.pack_rows(&rows);
    let (ktag, kp1, kp2) = kernel_tags(kernel);
    let mut out = Vec::with_capacity(64 + 16 * mat.nrows() + 8 * packed.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&kind.tag().to_le_bytes());
    out.extend_from_slice(&ktag.to_le_bytes());
    out.extend_from_slice(&kp1.to_le_bytes());
    out.extend_from_slice(&kp2.to_le_bytes());
    out.extend_from_slice(&lambda.to_le_bytes());
    out.extend_from_slice(&(mat.nrows() as u64).to_le_bytes());
    out.extend_from_slice(&(mat.ncols() as u64).to_le_bytes());
    out.extend_from_slice(&(mat.nnz() as u64).to_le_bytes());
    for &c in coef {
        out.extend_from_slice(&c.to_le_bytes());
    }
    for i in 0..mat.nrows() {
        out.extend_from_slice(&(mat.row_nnz(i) as u64).to_le_bytes());
    }
    for &w in &packed {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out
}

/// Write a model file (see the module docs for the layout).
pub fn write_model(
    path: &std::path::Path,
    kind: ModelKind,
    kernel: Kernel,
    lambda: f64,
    mat: &Csr,
    coef: &[f64],
) -> Result<()> {
    std::fs::write(path, model_bytes(kind, kernel, lambda, mat, coef))
        .map_err(|e| anyhow!("writing model to {path:?}: {e}"))
}

/// A strict little-endian cursor: every read names the field it was
/// reading, so truncation errors point at the first missing byte's
/// meaning instead of a generic "unexpected EOF".
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize, field: &str) -> Result<&'a [u8]> {
        ensure!(
            self.pos + n <= self.bytes.len(),
            "invalid value for 'model.{field}': file truncated at byte {} \
             ({} bytes needed, {} remain)",
            self.pos,
            n,
            self.bytes.len() - self.pos
        );
        let out = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u32(&mut self, field: &str) -> Result<u32> {
        let b = self.take(4, field)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, field: &str) -> Result<u64> {
        let b = self.take(8, field)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8-byte slice")))
    }

    fn f64(&mut self, field: &str) -> Result<f64> {
        Ok(f64::from_bits(self.u64(field)?))
    }
}

/// Decode and validate a `.kcd` byte stream.
pub fn parse_model(bytes: &[u8]) -> Result<RawModel> {
    let mut cur = Cursor { bytes, pos: 0 };
    let magic = cur.take(MAGIC.len(), "magic")?;
    ensure!(
        magic == MAGIC,
        "invalid value for 'model.magic': not a .kcd model file \
         (expected the KCDMODEL prefix)"
    );
    let version = cur.u32("version")?;
    ensure!(
        version == VERSION,
        "invalid value for 'model.version': this build reads format \
         version {VERSION}, got {version}"
    );
    let kind_tag = cur.u32("kind")?;
    let kind = ModelKind::from_tag(kind_tag).ok_or_else(|| {
        anyhow!("invalid value for 'model.kind': expected 0 (svm) or 1 (krr), got {kind_tag}")
    })?;
    let ktag = cur.u32("kernel")?;
    let kp1 = cur.f64("kernel")?;
    let kp2 = cur.f64("kernel")?;
    let kernel = match ktag {
        0 => Kernel::Linear,
        1 => {
            ensure!(
                kp2.is_finite() && kp2 >= 1.0 && kp2.fract() == 0.0 && kp2 <= f64::from(i32::MAX),
                "invalid value for 'model.kernel': poly degree must be a \
                 positive integer, got {kp2}"
            );
            ensure!(
                kp1.is_finite(),
                "invalid value for 'model.kernel': poly offset must be finite, got {kp1}"
            );
            Kernel::Poly {
                c: kp1,
                // Range-checked above; the cast is exact.
                d: kp2 as i32,
            }
        }
        2 => {
            ensure!(
                kp1.is_finite() && kp1 > 0.0,
                "invalid value for 'model.kernel': rbf sigma must be positive, got {kp1}"
            );
            Kernel::Rbf { sigma: kp1 }
        }
        other => bail!(
            "invalid value for 'model.kernel': expected 0 (linear), 1 (poly) \
             or 2 (rbf), got {other}"
        ),
    };
    let lambda = cur.f64("lambda")?;
    if kind == ModelKind::Krr {
        ensure!(
            lambda.is_finite() && lambda > 0.0,
            "invalid value for 'model.lambda': krr models need a positive \
             ridge penalty, got {lambda}"
        );
    }
    let rows = cur.u64("rows")? as usize;
    let cols = cur.u64("cols")? as usize;
    let nnz = cur.u64("nnz")? as usize;
    // The three length headers promise the exact remaining byte count;
    // check it up front so a truncated tail or an inflated nnz is caught
    // as the header lie it is, before any per-entry work.
    let body = rows
        .checked_mul(16)
        .and_then(|c| nnz.checked_mul(16).map(|p| (c, p)))
        .ok_or_else(|| {
            anyhow!("invalid value for 'model.rows': {rows} rows / {nnz} entries overflow")
        })?;
    let promised = cur.pos + body.0 + body.1;
    ensure!(
        bytes.len() == promised,
        "invalid value for 'model.nnz': header promises {rows} rows and \
         {nnz} entries ({promised} bytes), but the file holds {} bytes",
        bytes.len()
    );
    ensure!(
        nnz <= rows.saturating_mul(cols),
        "invalid value for 'model.nnz': {nnz} entries cannot fit in a \
         {rows}x{cols} matrix"
    );
    let mut coef = Vec::with_capacity(rows);
    for i in 0..rows {
        let c = cur.f64("coef")?;
        ensure!(
            c.is_finite(),
            "invalid value for 'model.coef': coefficient {i} is not finite ({c})"
        );
        coef.push(c);
    }
    let mut row_nnz = Vec::with_capacity(rows);
    let mut total = 0usize;
    for i in 0..rows {
        let n = cur.u64("row_nnz")? as usize;
        ensure!(
            n <= cols,
            "invalid value for 'model.row_nnz': row {i} claims {n} entries \
             in {cols} columns"
        );
        total += n;
        row_nnz.push(n);
    }
    ensure!(
        total == nnz,
        "invalid value for 'model.row_nnz': per-row counts sum to {total}, \
         but the header nnz is {nnz}"
    );
    let mut packed = Vec::with_capacity(2 * nnz);
    for _ in 0..nnz {
        let j = cur.f64("packed")?;
        let v = cur.f64("packed")?;
        // `from_packed` asserts (panics) on a bad column; re-state its
        // preconditions as load errors.
        ensure!(
            j.is_finite() && j >= 0.0 && j.fract() == 0.0 && (j as usize) < cols,
            "invalid value for 'model.packed': column index {j} is not a \
             valid column of a {cols}-column matrix"
        );
        packed.push(j);
        packed.push(v);
    }
    // Ascending-column order within each row is what `pack_rows` wrote
    // and what the merge-join prediction kernels assume.
    let mut off = 0usize;
    for (i, &n) in row_nnz.iter().enumerate() {
        for k in 1..n {
            let prev = packed[2 * (off + k - 1)];
            let here = packed[2 * (off + k)];
            ensure!(
                here > prev,
                "invalid value for 'model.packed': row {i} columns are not \
                 strictly ascending ({prev} then {here})"
            );
        }
        off += n;
    }
    let mat = Csr::from_packed(cols, &row_nnz, &packed);
    Ok(RawModel {
        kind,
        kernel,
        lambda,
        mat,
        coef,
    })
}

/// Read and validate a `.kcd` model file.
pub fn read_model(path: &std::path::Path) -> Result<RawModel> {
    let bytes = std::fs::read(path).map_err(|e| anyhow!("reading model {path:?}: {e}"))?;
    parse_model(&bytes)
}

/// What one grid cell `(group, col)` of a [`GridStorage::Sharded`] run
/// keeps resident: the block-cyclic row group of one feature shard
/// (`≈m/pr × ≈n/pc`). [`shard_cells`] produces them and
/// [`assemble_cells`] reassembles the full matrix — through the same
/// `pack_rows`/`from_packed` kernels the save path uses — so model
/// extraction works from sharded storage without ever materializing the
/// replicated matrix on a single cell first.
///
/// [`GridStorage::Sharded`]: crate::gram::GridStorage::Sharded
#[derive(Clone, Debug)]
pub struct CellShard {
    /// Block-cyclic row-group index in `[0, pr)`.
    pub group: usize,
    /// Feature-shard index in `[0, pc)`.
    pub col: usize,
    /// The resident rows (columns re-indexed to the shard).
    pub rows: Csr,
}

/// Split a training matrix into the `pr × pc` cell shards a
/// `GridStorage::Sharded` grid run stores, exactly as the grid layout
/// builds them: feature shard `col` of [`Csr::partition_cols`], rows
/// filtered to block-cyclic group `group` ([`crate::gram::block_cyclic_rows`]).
pub fn shard_cells(a: &Csr, pr: usize, pc: usize, row_block: usize) -> Vec<CellShard> {
    assert!(pr >= 1 && pc >= 1 && row_block >= 1);
    let shards = a.partition_cols(pc);
    let mut cells = Vec::with_capacity(pr * pc);
    for (col, shard) in shards.iter().enumerate() {
        for group in 0..pr {
            let rows = crate::gram::block_cyclic_rows(a.nrows(), pr, group, row_block);
            cells.push(CellShard {
                group,
                col,
                rows: shard.gather_rows(&rows),
            });
        }
    }
    cells
}

/// Reassemble the full `m × n` training matrix from sharded grid cells,
/// routing every cell's rows through the `pack_rows` → `from_packed`
/// serialization kernels (the rebuilt rows are bitwise identical to the
/// stored ones, so the assembled matrix is bitwise identical to the
/// replicated original). The cells may arrive in any order; each stored
/// entry has a unique global position, so the triplet assembly cannot
/// merge or reorder values.
pub fn assemble_cells(
    m: usize,
    n: usize,
    pr: usize,
    pc: usize,
    row_block: usize,
    cells: &[CellShard],
) -> Result<Csr> {
    ensure!(
        cells.len() == pr * pc,
        "invalid value for 'model.cells': a {pr}x{pc} grid stores {} cells, got {}",
        pr * pc,
        cells.len()
    );
    let width = n.div_ceil(pc);
    let mut trips: Vec<(usize, usize, f64)> = Vec::new();
    for cell in cells {
        ensure!(
            cell.group < pr && cell.col < pc,
            "invalid value for 'model.cells': cell ({}, {}) is outside the {pr}x{pc} grid",
            cell.group,
            cell.col
        );
        let owned = crate::gram::block_cyclic_rows(m, pr, cell.group, row_block);
        ensure!(
            cell.rows.nrows() == owned.len(),
            "invalid value for 'model.cells': cell ({}, {}) holds {} rows, \
             but its block-cyclic group owns {}",
            cell.group,
            cell.col,
            cell.rows.nrows(),
            owned.len()
        );
        let c0 = (cell.col * width).min(n);
        // The serialization kernels: pack the cell's resident rows and
        // rebuild them verbatim, exactly what a sharded rank would send.
        let all: Vec<usize> = (0..cell.rows.nrows()).collect();
        let packed = cell.rows.pack_rows(&all);
        let row_nnz: Vec<usize> = all.iter().map(|&i| cell.rows.row_nnz(i)).collect();
        let rebuilt = Csr::from_packed(cell.rows.ncols(), &row_nnz, &packed);
        for (local, &global) in owned.iter().enumerate() {
            for (j, v) in rebuilt.row_iter(local) {
                trips.push((global, c0 + j, v));
            }
        }
    }
    Ok(Csr::from_triplets(m, n, &trips))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_uniform_sparse, SynthParams, Task};

    fn sample_matrix() -> Csr {
        gen_uniform_sparse(
            SynthParams {
                m: 23,
                n: 17,
                density: 0.2,
                seed: 42,
            },
            Task::Classification,
        )
        .a
    }

    fn bits(m: &Csr) -> (Vec<usize>, Vec<u64>) {
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for i in 0..m.nrows() {
            let (c, v) = m.row_parts(i);
            cols.extend_from_slice(c);
            vals.extend(v.iter().map(|x| x.to_bits()));
        }
        (cols, vals)
    }

    #[test]
    fn byte_roundtrip_is_bitwise() {
        let a = sample_matrix();
        let coef: Vec<f64> = (0..a.nrows()).map(|i| (i as f64) * 0.137 - 1.0).collect();
        let kernel = Kernel::Poly { c: 0.5, d: 3 };
        let bytes = model_bytes(ModelKind::Svm, kernel, 0.0, &a, &coef);
        let raw = parse_model(&bytes).unwrap();
        assert_eq!(raw.kind, ModelKind::Svm);
        assert_eq!(raw.kernel, kernel);
        assert_eq!(raw.mat.nrows(), a.nrows());
        assert_eq!(raw.mat.ncols(), a.ncols());
        assert_eq!(bits(&raw.mat), bits(&a));
        let cb: Vec<u64> = coef.iter().map(|c| c.to_bits()).collect();
        let rb: Vec<u64> = raw.coef.iter().map(|c| c.to_bits()).collect();
        assert_eq!(cb, rb);
    }

    #[test]
    fn truncation_and_header_lies_are_named_errors() {
        let a = sample_matrix();
        let coef = vec![1.0; a.nrows()];
        let bytes = model_bytes(ModelKind::Krr, Kernel::Linear, 2.0, &a, &coef);

        // Truncation anywhere in the stream is a hard error.
        for cut in [4, 11, 20, bytes.len() - 3] {
            let err = parse_model(&bytes[..cut]).unwrap_err().to_string();
            assert!(err.contains("invalid value for 'model."), "{err}");
        }

        // Version mismatch names the field.
        let mut v = bytes.clone();
        v[8] = 9;
        let err = parse_model(&v).unwrap_err().to_string();
        assert!(err.contains("'model.version'"), "{err}");

        // A corrupt kind tag names the field.
        let mut k = bytes.clone();
        k[12] = 7;
        let err = parse_model(&k).unwrap_err().to_string();
        assert!(err.contains("'model.kind'"), "{err}");

        // Inflating the nnz header makes the byte count a lie.
        let mut z = bytes.clone();
        let nnz_off = 8 + 4 + 4 + 4 + 8 + 8 + 8 + 8 + 8;
        let bad = (a.nnz() as u64 + 1).to_le_bytes();
        z[nnz_off..nnz_off + 8].copy_from_slice(&bad);
        let err = parse_model(&z).unwrap_err().to_string();
        assert!(err.contains("'model.nnz'"), "{err}");
    }

    #[test]
    fn sharded_cells_reassemble_bitwise() {
        let a = sample_matrix();
        for (pr, pc) in [(1, 2), (2, 2), (3, 1), (2, 3)] {
            for rb in [1, 4] {
                let cells = shard_cells(&a, pr, pc, rb);
                let b = assemble_cells(a.nrows(), a.ncols(), pr, pc, rb, &cells).unwrap();
                assert_eq!(bits(&b), bits(&a), "grid {pr}x{pc} rb {rb}");
            }
        }
        // Wrong cell count is a named hard error.
        let cells = shard_cells(&a, 2, 2, 2);
        let err = assemble_cells(a.nrows(), a.ncols(), 2, 3, 2, &cells)
            .unwrap_err()
            .to_string();
        assert!(err.contains("'model.cells'"), "{err}");
    }
}
