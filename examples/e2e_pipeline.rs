//! End-to-end driver: proves every layer composes on a real workload.
//!
//! Pipeline (recorded in EXPERIMENTS.md §E2E):
//!   1. Generate a dense binary-classification workload (m=2048, n=128).
//!   2. **L1/L2/runtime**: train K-SVM-L1 (RBF) with s-step DCD where the
//!      kernel hot-spot executes the AOT-compiled JAX/Pallas artifact via
//!      PJRT (`artifacts/gram_rbf_m2048_n128_k*.hlo.txt`).
//!   3. **L3**: train the same problem through the distributed engine
//!      (P = 8 ranks, 1D-column shards, real allreduces) with the native
//!      f64 path, and verify the two stacks agree.
//!   4. Verify s-step ≡ classical on the distributed path.
//!   5. Train K-RR (b = 64, s = 16) and compare to the closed form.
//!   6. Report metrics: duality gap, accuracy, iteration throughput,
//!      phase breakdown, projected Cray-EX speedup of s-step vs classical.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use std::time::Instant;

use kcd::comm::AllreduceAlgo;
use kcd::coordinator::{run_distributed, ProblemSpec, SolverSpec};
use kcd::costmodel::{Ledger, MachineProfile, Phase};
use kcd::data::gen_dense_classification;
use kcd::kernelfn::Kernel;
use kcd::runtime::{PjrtGram, PjrtRuntime};
use kcd::solvers::objective::SvmObjective;
use kcd::solvers::{dcd_sstep, krr_exact, LocalGram, SvmParams, SvmVariant};

const M: usize = 2048;
const N: usize = 128;
const H: usize = 4096;
const S: usize = 32;
const SEED: u64 = 20240710;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (m, h) = if quick { (256, 1024) } else { (M, H) };
    println!("=== kcd end-to-end pipeline (m={m}, n={N}, H={h}, s={S}) ===\n");

    // ---------------------------------------------------------------- 1.
    let t0 = Instant::now();
    let n = if quick { 64 } else { N };
    let mut ds = gen_dense_classification(m, n, 0.05, SEED);
    // Feature scaling (LIBSVM datasets ship normalized): 1/√n features
    // keep the RBF kernel well-conditioned (‖a_i − a_j‖² ≈ 2 instead of
    // ≈ 2n, which would degenerate K to the identity).
    {
        let mut a = ds.a.to_dense();
        let scale = 1.0 / (n as f64).sqrt();
        for v in a.data_mut() {
            *v *= scale;
        }
        ds.a = kcd::sparse::Csr::from_dense(&a);
    }
    let a_dense = ds.a.to_dense();
    println!(
        "[1] workload: {} ({}×{}, {:.0}% dense) in {:.2}s",
        ds.name,
        ds.m(),
        ds.n(),
        100.0 * ds.a.density(),
        t0.elapsed().as_secs_f64()
    );

    let kernel = Kernel::paper_rbf();
    let params = SvmParams {
        c: 1.0,
        variant: SvmVariant::L1,
        h,
        seed: SEED,
    };

    // ---------------------------------------------------------------- 2.
    let dir = PjrtRuntime::default_dir();
    let alpha_pjrt = match PjrtRuntime::open(&dir) {
        Ok(rt) => {
            println!("[2] PJRT: platform={}, artifacts={}", rt.platform(), rt.manifest().artifacts().len());
            let mut oracle = PjrtGram::new(rt, &a_dense, kernel).expect("artifact for shape");
            let mut ledger = Ledger::new();
            let t = Instant::now();
            let alpha = dcd_sstep(&mut oracle, &ds.y, &params, S, &mut ledger, None);
            let dt = t.elapsed().as_secs_f64();
            println!(
                "    s-step DCD over AOT JAX/Pallas kernel: {h} iters in {dt:.2}s \
                 ({:.0} iters/s, kernel wall {:.2}s)",
                h as f64 / dt,
                ledger.wall_secs(Phase::KernelCompute)
            );
            Some(alpha)
        }
        Err(e) => {
            println!("[2] PJRT path skipped ({e:#}); run `make artifacts`");
            None
        }
    };

    // ---------------------------------------------------------------- 3.
    let machine = MachineProfile::cray_ex();
    let problem = ProblemSpec::Svm {
        c: 1.0,
        variant: SvmVariant::L1,
    };
    let solver = SolverSpec {
        s: S,
        h,
        seed: SEED,
        ..Default::default()
    };
    let t = Instant::now();
    let dist = run_distributed(
        &ds,
        kernel,
        &problem,
        &solver,
        8,
        AllreduceAlgo::Rabenseifner,
        &machine,
    );
    println!(
        "[3] distributed (P=8, rabenseifner): {h} iters in {:.2}s local wall",
        t.elapsed().as_secs_f64()
    );
    if let Some(ap) = &alpha_pjrt {
        let dev = kcd::dense::rel_err(ap, &dist.alpha);
        println!("    PJRT(f32) vs distributed-native(f64) solution deviation: {dev:.2e}");
        assert!(dev < 5e-3, "stacks disagree: {dev}");
    }

    // ---------------------------------------------------------------- 4.
    let classical = run_distributed(
        &ds,
        kernel,
        &problem,
        &SolverSpec { s: 1, ..solver },
        8,
        AllreduceAlgo::Rabenseifner,
        &machine,
    );
    let dev = kcd::dense::rel_err(&dist.alpha, &classical.alpha);
    println!("[4] s-step ≡ classical on the distributed path: ‖Δα‖/‖α‖ = {dev:.2e}");
    assert!(dev < 1e-10, "equivalence violated: {dev}");

    // ---------------------------------------------------------------- 5.
    let t = Instant::now();
    let reg = kcd::data::gen_dense_regression(if quick { 128 } else { 512 }, 32, 0.1, SEED);
    let mut oracle = LocalGram::new(reg.a.clone(), kernel);
    let astar = krr_exact(&mut oracle, &reg.y, 1.0);
    let krr = run_distributed(
        &reg,
        kernel,
        &ProblemSpec::Krr { lambda: 1.0, b: 64.min(reg.m()) },
        &SolverSpec {
            s: 16,
            h: 400,
            seed: SEED,
            ..Default::default()
        },
        4,
        AllreduceAlgo::Rabenseifner,
        &machine,
    );
    let rel = kcd::dense::rel_err(&krr.alpha, &astar);
    println!(
        "[5] K-RR (b=64, s=16, P=4): relative error vs closed form = {rel:.2e} ({:.2}s)",
        t.elapsed().as_secs_f64()
    );
    assert!(rel < 1e-6, "K-RR did not converge: {rel}");

    // ---------------------------------------------------------------- 6.
    let mut oracle = LocalGram::new(ds.a.clone(), kernel);
    let obj = SvmObjective::new(&mut oracle, &ds.y, params.c, params.variant);
    let gap = obj.duality_gap(&dist.alpha);
    let acc = obj.train_accuracy(&dist.alpha);
    println!("\n[6] model quality:");
    println!("    duality gap      = {gap:.4e}");
    println!("    train accuracy   = {:.2}%", acc * 100.0);

    println!("\n    projected Cray-EX time (P=8), per phase:");
    for run in [("classical", &classical), ("s-step", &dist)] {
        let p = &run.1.projection;
        println!(
            "      {:<10} total {:.3e}s | kernel {:.2e} allreduce {:.2e} solve {:.2e} \
             gradcorr {:.2e} memreset {:.2e}",
            run.0,
            p.total_secs(),
            p.phase_secs(Phase::KernelCompute),
            p.phase_secs(Phase::Allreduce),
            p.phase_secs(Phase::Solve),
            p.phase_secs(Phase::GradCorr),
            p.phase_secs(Phase::MemReset),
        );
    }
    let speedup = classical.projection.total_secs() / dist.projection.total_secs();
    println!("    headline: s-step DCD projected speedup over DCD at P=8: {speedup:.2}x");
    println!(
        "    allreduce rounds: classical {} → s-step {} ({}x fewer)",
        classical.critical.comm.rounds,
        dist.critical.comm.rounds,
        classical.critical.comm.rounds / dist.critical.comm.rounds.max(1)
    );
    assert!(acc > 0.9, "accuracy too low: {acc}");
    assert!(speedup > 1.0, "s-step should win at P=8: {speedup}");
    println!("\nE2E OK");
}
