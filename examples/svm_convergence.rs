//! Figure 1 scenario: duality-gap convergence of DCD vs s-step DCD for
//! K-SVM-L1 and K-SVM-L2 on duke- and diabetes-like datasets, all three
//! kernels. The s-step series must overlay the classical series to
//! machine precision — run with `--csv` to get plottable series.
//!
//! ```bash
//! cargo run --release --example svm_convergence [-- --csv] [-- --quick]
//! ```

use kcd::coordinator::figures::{max_series_deviation, svm_gap_series};
use kcd::data::paper_dataset;
use kcd::kernelfn::Kernel;
use kcd::solvers::SvmVariant;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let csv = args.iter().any(|a| a == "--csv");
    let quick = args.iter().any(|a| a == "--quick");
    let h = if quick { 512 } else { 4096 };
    let every = h / 32;

    for name in ["duke", "diabetes"] {
        let scale = if quick && name == "diabetes" { 0.2 } else { 1.0 };
        let ds = paper_dataset(name).unwrap().generate_scaled(scale);
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            for variant in [SvmVariant::L1, SvmVariant::L2] {
                let classical =
                    svm_gap_series(&ds, kernel, variant, 1.0, h, 1, 11, every);
                let sstep = svm_gap_series(&ds, kernel, variant, 1.0, h, 16, 11, every);
                let dev = max_series_deviation(&classical, &sstep);
                if csv {
                    for ((k, g1), (_, g2)) in classical.iter().zip(&sstep) {
                        println!(
                            "{name},{},{:?},{k},{g1:.12e},{g2:.12e}",
                            kernel.name(),
                            variant
                        );
                    }
                } else {
                    println!(
                        "{name:<10} {:<7} {:?}: gap {:.3e} → {:.3e} over {h} iters; \
                         s-step overlay deviation {dev:.2e}",
                        kernel.name(),
                        variant,
                        classical.first().unwrap().1,
                        classical.last().unwrap().1,
                    );
                }
                assert!(dev < 1e-7, "s-step must overlay classical (dev {dev})");
            }
        }
    }
    if !csv {
        println!("\nAll s-step series overlay their classical counterparts. (Fig 1 ✓)");
    }
}
