//! Figure 2 scenario: relative-solution-error convergence of BDCD vs
//! s-step BDCD for K-RR on abalone- and bodyfat-like datasets, all three
//! kernels, at the paper's settings (abalone: b=128; bodyfat: b=64;
//! s ∈ {16, 256}).
//!
//! ```bash
//! cargo run --release --example krr_convergence [-- --csv] [-- --quick]
//! ```

use kcd::coordinator::figures::{krr_relerr_series_vs, max_series_deviation};
use kcd::data::paper_dataset;
use kcd::kernelfn::Kernel;
use kcd::solvers::{krr_exact, LocalGram};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let csv = args.iter().any(|a| a == "--csv");
    let quick = args.iter().any(|a| a == "--quick");

    // (dataset, scale, b, H) — abalone is the big MATLAB dataset (m=4177);
    // quick mode scales it down so the closed-form solve stays snappy.
    let cases = [
        ("abalone", if quick { 0.1 } else { 0.25 }, 128usize, 3000usize),
        ("bodyfat", 1.0, 64, 2000),
    ];
    for (name, scale, b, h) in cases {
        let ds = paper_dataset(name).unwrap().generate_scaled(scale);
        let b = b.min(ds.m() / 2).max(1);
        let every = h / 25;
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let mut oracle = LocalGram::new(ds.a.clone(), kernel);
            let astar = krr_exact(&mut oracle, &ds.y, 1.0);
            let classical =
                krr_relerr_series_vs(&ds, kernel, 1.0, b, h, 1, 13, every, &astar);
            for s in [16usize, 256] {
                let sstep =
                    krr_relerr_series_vs(&ds, kernel, 1.0, b, h, s, 13, every, &astar);
                let dev = max_series_deviation(&classical, &sstep);
                if csv {
                    for ((k, e1), (_, e2)) in classical.iter().zip(&sstep) {
                        println!("{name},{},{s},{k},{e1:.12e},{e2:.12e}", kernel.name());
                    }
                } else {
                    println!(
                        "{name:<9} {:<7} b={b:<4} s={s:<4}: relerr {:.3e} → {:.3e}; \
                         overlay deviation {dev:.2e}",
                        kernel.name(),
                        classical.first().unwrap().1,
                        classical.last().unwrap().1,
                    );
                }
                assert!(
                    dev < 1e-7,
                    "{name}/{}/s={s}: s-step must overlay classical (dev {dev})",
                    kernel.name()
                );
            }
        }
    }
    if !csv {
        println!("\nAll s-step BDCD series overlay BDCD, s up to 256. (Fig 2 ✓)");
    }
}
