//! Quickstart: train a kernel SVM with s-step DCD in ~20 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use kcd::costmodel::Ledger;
use kcd::data::gen_dense_classification;
use kcd::kernelfn::Kernel;
use kcd::solvers::objective::SvmObjective;
use kcd::solvers::{dcd_sstep, LocalGram, SvmParams, SvmVariant};

fn main() {
    // 1. A dataset: 500 points, 16 features, 5% label noise.
    let ds = gen_dense_classification(500, 16, 0.05, 42);

    // 2. A kernel and the solver parameters (paper defaults: RBF σ = 1).
    let kernel = Kernel::paper_rbf();
    let params = SvmParams {
        c: 1.0,
        variant: SvmVariant::L1,
        h: 4000,
        seed: 7,
    };

    // 3. Train with s-step DCD (s = 32: one communication round per 32
    //    updates when run distributed; identical solution either way).
    let mut oracle = LocalGram::new(ds.a.clone(), kernel);
    let mut ledger = Ledger::new();
    let alpha = dcd_sstep(&mut oracle, &ds.y, &params, 32, &mut ledger, None);

    // 4. Inspect the model.
    let mut oracle2 = LocalGram::new(ds.a.clone(), kernel);
    let obj = SvmObjective::new(&mut oracle2, &ds.y, params.c, params.variant);
    println!("duality gap    : {:.3e}", obj.duality_gap(&alpha));
    println!("train accuracy : {:.1}%", 100.0 * obj.train_accuracy(&alpha));
    println!("support vectors: {}", alpha.iter().filter(|a| **a > 0.0).count());
    println!(
        "kernel flops   : {:.2e}",
        ledger.flops(kcd::costmodel::Phase::KernelCompute)
    );
}
