//! Figure 3 scenario: strong scaling of DCD vs s-step DCD for K-SVM on
//! the performance datasets, mixing measured ranks (real threads + real
//! message traffic, small P) with count-projected points (large P).
//!
//! ```bash
//! cargo run --release --example strong_scaling [-- --quick]
//! ```

use kcd::comm::AllreduceAlgo;
use kcd::coordinator::report::scaling_table;
use kcd::coordinator::scaling::{sweep, SweepConfig};
use kcd::coordinator::ProblemSpec;
use kcd::costmodel::MachineProfile;
use kcd::data::paper_dataset;
use kcd::kernelfn::Kernel;
use kcd::solvers::SvmVariant;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let machine = MachineProfile::cray_ex();
    let problem = ProblemSpec::Svm {
        c: 1.0,
        variant: SvmVariant::L1,
    };
    let cfg = SweepConfig {
        p_list: vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 512],
        s_list: vec![2, 4, 8, 16, 32, 64, 128, 256],
        t_list: vec![1],
        pr: 1,
        h: if quick { 64 } else { 512 },
        seed: 3,
        algo: AllreduceAlgo::Rabenseifner,
        measured_limit: if quick { 2 } else { 8 },
        auto_tune: false,
        ..Default::default()
    };
    let synth_scale = if quick { 0.01 } else { 0.1 };
    for (name, scale) in [("colon-cancer", 1.0), ("duke", 1.0), ("synthetic", synth_scale)] {
        let ds = paper_dataset(name).unwrap().generate_scaled(scale);
        println!(
            "\n## {} ({}×{}, {:.2}% dense) — K-SVM RBF strong scaling",
            ds.name,
            ds.m(),
            ds.n(),
            100.0 * ds.a.density()
        );
        let rows = sweep(&ds, Kernel::paper_rbf(), &problem, &cfg, &machine);
        print!("{}", scaling_table(&rows).markdown());
        let best = rows
            .iter()
            .map(|r| r.speedup())
            .fold(0.0f64, f64::max);
        println!("max s-step speedup across P: {best:.2}x");
    }
}
