//! Table 4: speedups of s-step BDCD over BDCD for K-RR at block sizes
//! b ∈ {1, 2, 4}, on colon-cancer-, duke- and news20-like datasets, all
//! three kernels.
//!
//! Reproduction target (paper): speedups shrink monotonically as b grows
//! for every dataset/kernel (b=1 ≈ 4–5.5×, b=4 ≈ 1.1–2.6×), because the
//! allreduce message is b·m words and larger b pushes the method from the
//! latency-bound into the bandwidth-bound regime.

use kcd::bench_harness::{quick_mode, section};
use kcd::comm::AllreduceAlgo;
use kcd::coordinator::scaling::{sweep, SweepConfig};
use kcd::coordinator::ProblemSpec;
use kcd::costmodel::MachineProfile;
use kcd::data::paper_dataset;
use kcd::kernelfn::Kernel;

fn main() {
    let quick = quick_mode();
    section("Table 4 — s-step BDCD speedup over BDCD vs block size b");
    let machine = MachineProfile::cray_ex();
    // P per dataset: the small dense sets scale to O(10) ranks (Fig 3), so
    // their Table-4 point is P = 32 (also keeps the b·m-word allreduce
    // above the small-message fallback threshold); news20 uses P = 2048.
    let cases = [
        ("colon-cancer", 1.0, 32usize),
        ("duke", 1.0, 32),
        ("news20", if quick { 0.1 } else { 0.5 }, 2048),
    ];
    let kernels = [
        ("Linear", Kernel::Linear),
        ("Polynomial", Kernel::paper_poly()),
        ("Gauss", Kernel::paper_rbf()),
    ];
    println!("| dataset | kernel | b=1 | b=2 | b=4 |");
    println!("|---|---|---|---|---|");
    let mut all_monotone = true;
    for (name, scale, p) in cases {
        let ds = paper_dataset(name).unwrap().generate_scaled(scale);
        for (kname, kernel) in kernels {
            let mut speedups = Vec::new();
            for b in [1usize, 2, 4] {
                let cfg = SweepConfig {
                    p_list: vec![p],
                    s_list: vec![2, 4, 8, 16, 32, 64, 128, 256],
                    t_list: vec![1],
                    pr: 1,
                    h: if quick { 64 } else { 512 },
                    seed: 17,
                    algo: AllreduceAlgo::Rabenseifner,
                    measured_limit: 0, // projected engine at these P
                    auto_tune: false,
                    ..Default::default()
                };
                let rows = sweep(
                    &ds,
                    kernel,
                    &ProblemSpec::Krr { lambda: 1.0, b },
                    &cfg,
                    &machine,
                );
                speedups.push(rows[0].speedup());
            }
            println!(
                "| {} | {kname} | {:.2}x | {:.2}x | {:.2}x |",
                ds.name, speedups[0], speedups[1], speedups[2]
            );
            if !(speedups[0] >= speedups[1] && speedups[1] >= speedups[2]) {
                all_monotone = false;
                eprintln!("non-monotone: {name}/{kname}: {speedups:?}");
            }
            assert!(
                speedups[2] >= 0.9,
                "{name}/{kname}: b=4 should not lose badly"
            );
        }
    }
    println!("\npaper reference: colon b=1 up to 4.78x → b=4 1.7–2.5x; duke b=1 up to 5.48x");
    assert!(all_monotone, "Table 4 trend: speedup must shrink with b");
    println!("Table 4 shape reproduced: speedup decreases with block size ✓");
}
