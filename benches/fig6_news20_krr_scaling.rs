//! Figure 6: BDCD and s-step BDCD strong scaling on the news20-like
//! dataset for K-RR with b = 4 (RBF kernel).
//!
//! Reproduction target: with the larger block size both methods scale
//! well across the whole P range; the s-step win is modest (bandwidth-
//! bound regime) and s-step hits the load-imbalance limit before BDCD.

use kcd::bench_harness::{quick_mode, section};
use kcd::comm::AllreduceAlgo;
use kcd::coordinator::report::scaling_table;
use kcd::coordinator::scaling::{sweep, SweepConfig};
use kcd::coordinator::ProblemSpec;
use kcd::costmodel::MachineProfile;
use kcd::data::paper_dataset;
use kcd::kernelfn::Kernel;

fn main() {
    let quick = quick_mode();
    section("Figure 6 — news20.binary K-RR (b = 4, RBF) strong scaling");
    let scale = if quick { 0.1 } else { 0.5 };
    let ds = paper_dataset("news20").unwrap().generate_scaled(scale);
    let machine = MachineProfile::cray_ex();
    let problem = ProblemSpec::Krr { lambda: 1.0, b: 4 };
    let cfg = SweepConfig {
        p_list: vec![128, 256, 512, 1024, 2048, 4096],
        s_list: vec![4, 8, 16, 32, 64, 128, 256],
        t_list: vec![1],
        pr: 1,
        h: if quick { 64 } else { 512 },
        seed: 6,
        algo: AllreduceAlgo::Rabenseifner,
        measured_limit: 0,
        auto_tune: false,
        ..Default::default()
    };
    let rows = sweep(&ds, Kernel::paper_rbf(), &problem, &cfg, &machine);
    print!("{}", scaling_table(&rows).markdown());

    let speedups: Vec<f64> = rows.iter().map(|r| r.speedup()).collect();
    let max_speedup = speedups.iter().cloned().fold(0.0f64, f64::max);
    println!("\nmax s-step speedup: {max_speedup:.2}x (paper: modest, ~1.14x at P = 2048)");
    assert!(
        speedups.iter().all(|&s| s > 0.9),
        "s-step should never lose badly: {speedups:?}"
    );
    // s-step hits the bandwidth / load-imbalance floor no later than BDCD
    // (the paper's Fig 6 observation).
    let classical_gain =
        rows[0].classical.total_secs() / rows.last().unwrap().classical.total_secs();
    let sstep_gain =
        rows[0].best_sstep.total_secs() / rows.last().unwrap().best_sstep.total_secs();
    println!(
        "scaling gain P=128→4096: classical {classical_gain:.2}x, s-step {sstep_gain:.2}x"
    );
    if !quick {
        assert!(
            max_speedup < 3.0,
            "b = 4 on news20 must be bandwidth-capped, got {max_speedup}"
        );
        assert!(
            sstep_gain <= classical_gain * 1.05,
            "s-step should flatten no later than BDCD: {sstep_gain} vs {classical_gain}"
        );
    }
    println!("Fig 6 shape reproduced: modest bandwidth-capped s-step win, earlier flattening ✓");
}
