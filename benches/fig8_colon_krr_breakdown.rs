//! Figure 8: time composition of BDCD vs CA-(s-step-)BDCD on the
//! colon-cancer-like dataset.
//!
//! Reproduction target: the s-step method keeps reducing total time up
//! to s ≈ 32; past that point the extra bandwidth + overheads erase the
//! gains (total time regresses), and the allreduce share grows with the
//! process count (more latency-bound at P=32 than P=4).

use kcd::bench_harness::{quick_mode, section};
use kcd::comm::AllreduceAlgo;
use kcd::coordinator::breakdown::breakdown;
use kcd::coordinator::report::breakdown_table;
use kcd::coordinator::ProblemSpec;
use kcd::costmodel::{MachineProfile, Phase};
use kcd::data::paper_dataset;
use kcd::kernelfn::Kernel;

fn main() {
    let quick = quick_mode();
    section("Figure 8 — colon-cancer K-RR time composition, BDCD vs CA-BDCD");
    let ds = paper_dataset("colon-cancer").unwrap().generate();
    let machine = MachineProfile::cray_ex();
    let problem = ProblemSpec::Krr { lambda: 1.0, b: 1 };
    let h = if quick { 128 } else { 1024 };
    let s_list = [2usize, 8, 32, 128, 256];

    let mut ar_fraction = Vec::new();
    for p in [4usize, 32] {
        let bars = breakdown(
            &ds,
            Kernel::paper_rbf(),
            &problem,
            &s_list,
            h,
            p,
            1,
            AllreduceAlgo::Rabenseifner,
            &machine,
            if quick { 0 } else { 4 },
            kcd::gram::OverlapMode::Off,
        );
        println!("\n### P = {p}");
        print!("{}", breakdown_table(&bars).markdown());
        let classical_ar = bars[0].projection.phase_secs(Phase::Allreduce)
            / bars[0].projection.total_secs();
        ar_fraction.push(classical_ar);
        println!("classical allreduce share: {:.0}%", classical_ar * 100.0);

        if p == 32 {
            let t: Vec<f64> = bars.iter().map(|b| b.projection.total_secs()).collect();
            let best_i = (0..t.len()).min_by(|&a, &b| t[a].total_cmp(&t[b])).unwrap();
            println!(
                "best s = {} ({:.2}x over classical)",
                bars[best_i].s,
                t[0] / t[best_i]
            );
            assert!(best_i > 0, "some s must beat classical");
            // Diminishing returns: the gain from pushing s beyond 32 is a
            // small fraction of the gain up to 32. (The paper's measured
            // colon run additionally shows kernel time *regressing* past
            // s = 32 — a cache/TLB artifact its own cost analysis does
            // not predict; see EXPERIMENTS.md §Fig8.)
            let i32 = bars.iter().position(|b| b.s == 32).unwrap();
            let gain_to_32 = t[0] - t[i32];
            let gain_past_32 = (t[i32] - t[t.len() - 1]).max(0.0);
            assert!(
                gain_past_32 < 0.25 * gain_to_32,
                "returns must diminish past s=32: {t:?}"
            );
        }
    }
    assert!(
        ar_fraction[1] > ar_fraction[0],
        "allreduce share should grow with P: {ar_fraction:?}"
    );
    println!("\nFig 8 shape reproduced: interior optimal s, allreduce share grows with P ✓");
}
