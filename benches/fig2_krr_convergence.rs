//! Figure 2: BDCD vs s-step BDCD convergence (relative solution error vs
//! the closed-form α*) for K-RR — abalone-like (b=128) and bodyfat-like
//! (b=64) datasets, s ∈ {16, 256}, all three kernels.
//!
//! Reproduction target: s-step BDCD overlays BDCD to machine precision
//! even at s = 256 and b ≫ 1, and both reach the 1e-8 relative-error
//! tolerance the paper uses.

use kcd::bench_harness::{quick_mode, section};
use kcd::coordinator::figures::{iters_to_tol, krr_relerr_series_vs, max_series_deviation};
use kcd::coordinator::report::Table;
use kcd::data::paper_dataset;
use kcd::kernelfn::Kernel;
use kcd::solvers::{krr_exact, LocalGram};

fn main() {
    let quick = quick_mode();
    section("Figure 2 — K-RR relative-error convergence, BDCD vs s-step BDCD");

    // abalone is the paper's largest convergence dataset (m = 4177); the
    // closed-form reference is O(m³), so the default run uses a 0.25
    // scale stand-in (m ≈ 1044) and quick mode shrinks further.
    let cases = [
        ("abalone", if quick { 0.06 } else { 0.25 }, 128usize),
        ("bodyfat", 1.0, 64usize),
    ];
    let mut worst: f64 = 0.0;
    for (name, scale, b) in cases {
        let ds = paper_dataset(name).unwrap().generate_scaled(scale);
        let b = b.min(ds.m() / 4).max(1);
        let h = if quick { 600 } else { 4000 };
        let every = h / 20;
        let mut t = Table::new(vec![
            "kernel", "relerr@first", "final relerr", "iters→1e-8", "overlay s=16", "s=256",
        ]);
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let mut oracle = LocalGram::new(ds.a.clone(), kernel);
            let astar = krr_exact(&mut oracle, &ds.y, 1.0);
            let classical =
                krr_relerr_series_vs(&ds, kernel, 1.0, b, h, 1, 31, every, &astar);
            let mut devs = Vec::new();
            for s in [16usize, 256] {
                let ss = krr_relerr_series_vs(&ds, kernel, 1.0, b, h, s, 31, every, &astar);
                devs.push(max_series_deviation(&classical, &ss));
            }
            worst = worst.max(devs.iter().cloned().fold(0.0, f64::max));
            t.row(vec![
                kernel.name().to_string(),
                format!("{:.3e}", classical.first().unwrap().1),
                format!("{:.3e}", classical.last().unwrap().1),
                iters_to_tol(&classical, 1e-8)
                    .map(|k| k.to_string())
                    .unwrap_or_else(|| "—".into()),
                format!("{:.1e}", devs[0]),
                format!("{:.1e}", devs[1]),
            ]);
        }
        println!("\n### {} ({}×{}, b = {b})", ds.name, ds.m(), ds.n());
        print!("{}", t.markdown());
    }
    println!("\nworst overlay deviation (incl. s = 256): {worst:.2e}");
    assert!(worst < 1e-7, "Figure 2 reproduction failed");
    println!("Fig 2 shape reproduced: s-step BDCD ≡ BDCD, stable to s = 256 ✓");
}
