//! Ablations for the design choices and paper extensions:
//!
//! 1. **Allreduce algorithm** (DESIGN.md choice: rabenseifner default) —
//!    projected time of the three collective algorithms across message
//!    sizes and P.
//! 2. **CoCoA baseline** (related work, §2) — duality gap at equal
//!    communication rounds vs s-step DCD: s-step is exact, CoCoA trades
//!    convergence for communication.
//! 3. **Nyström kernel approximation** (the paper's stated future work)
//!    — approximation error and kernel-flop savings vs landmark count.
//! 4. **Machine profile** (cloud vs Cray-EX) — the paper's conclusion
//!    predicts bigger s-step wins where latency is worse; verify.

use kcd::bench_harness::{quick_mode, section};
use kcd::comm::AllreduceAlgo;
use kcd::coordinator::report::Table;
use kcd::coordinator::scaling::{sweep, SweepConfig};
use kcd::coordinator::ProblemSpec;
use kcd::costmodel::{Ledger, MachineProfile, Phase};
use kcd::data::paper_dataset;
use kcd::kernelfn::Kernel;
use kcd::solvers::objective::SvmObjective;
use kcd::solvers::{
    cocoa_svm, dcd_sstep, CocoaParams, LocalGram, NystromGram, SvmParams, SvmVariant,
};

fn main() {
    let quick = quick_mode();
    ablation_allreduce(quick);
    ablation_cocoa(quick);
    ablation_nystrom(quick);
    ablation_machine(quick);
    println!("\nablations done ✓");
}

fn ablation_allreduce(quick: bool) {
    section("Ablation 1 — allreduce algorithm (projected, duke K-SVM)");
    let ds = paper_dataset("duke").unwrap().generate();
    let machine = MachineProfile::cray_ex();
    let problem = ProblemSpec::Svm {
        c: 1.0,
        variant: SvmVariant::L1,
    };
    let mut t = Table::new(vec!["algo", "P=64 classical", "P=64 best s-step", "speedup"]);
    let mut best_total = f64::MAX;
    let mut best_algo = "";
    for algo in [
        AllreduceAlgo::Rabenseifner,
        AllreduceAlgo::RecursiveDoubling,
        AllreduceAlgo::Linear,
    ] {
        let cfg = SweepConfig {
            p_list: vec![64],
            s_list: vec![8, 32, 128],
            t_list: vec![1],
            pr: 1,
            h: if quick { 64 } else { 512 },
            seed: 1,
            algo,
            measured_limit: 0,
            auto_tune: false,
            ..Default::default()
        };
        let rows = sweep(&ds, Kernel::paper_rbf(), &problem, &cfg, &machine);
        let r = &rows[0];
        t.row(vec![
            algo.name().to_string(),
            format!("{:.3e}", r.classical.total_secs()),
            format!("{:.3e}", r.best_sstep.total_secs()),
            format!("{:.2}x", r.speedup()),
        ]);
        if r.best_sstep.total_secs() < best_total {
            best_total = r.best_sstep.total_secs();
            best_algo = algo.name();
        }
    }
    print!("{}", t.markdown());
    println!("fastest end-to-end: {best_algo}");
}

fn ablation_cocoa(quick: bool) {
    section("Ablation 2 — CoCoA vs s-step DCD at equal communication (linear K-SVM)");
    let ds = paper_dataset("diabetes")
        .unwrap()
        .generate_scaled(if quick { 0.15 } else { 0.5 });
    let c = 1.0;
    let mut oracle = LocalGram::new(ds.a.clone(), Kernel::Linear);
    let obj = SvmObjective::new(&mut oracle, &ds.y, c, SvmVariant::L1);
    let rounds = if quick { 20 } else { 50 };
    let k_workers = 8;

    let mut t = Table::new(vec![
        "method",
        "comm rounds",
        "total updates",
        "final duality gap",
    ]);
    // s-step DCD with s chosen so communications == rounds.
    let s = 16usize;
    let h = rounds * s;
    let p = SvmParams {
        c,
        variant: SvmVariant::L1,
        h,
        seed: 11,
    };
    let mut o = LocalGram::new(ds.a.clone(), Kernel::Linear);
    let alpha_sstep = dcd_sstep(&mut o, &ds.y, &p, s, &mut Ledger::new(), None);
    let gap_sstep = obj.duality_gap(&alpha_sstep);
    t.row(vec![
        format!("s-step DCD (s={s})"),
        rounds.to_string(),
        h.to_string(),
        format!("{:.4e}", gap_sstep),
    ]);

    // CoCoA at the same number of communication rounds, with increasing
    // local work (its knob for "communicate less").
    let mut gaps = Vec::new();
    for local in [2usize, 16, 128] {
        let cp = CocoaParams {
            k_workers,
            rounds,
            local_iters: local,
            c,
            variant: SvmVariant::L1,
            seed: 11,
        };
        let res = cocoa_svm(&ds, &cp, &mut Ledger::new());
        let gap = obj.duality_gap(&res.alpha);
        gaps.push(gap);
        t.row(vec![
            format!("CoCoA (K={k_workers}, T={local})"),
            rounds.to_string(),
            (rounds * k_workers * local).to_string(),
            format!("{gap:.4e}"),
        ]);
    }
    print!("{}", t.markdown());
    // Shape: s-step attains the sequential method's progress exactly; it
    // must beat CoCoA at the matched communication budget even though
    // CoCoA does more raw updates.
    assert!(
        gap_sstep < gaps[0],
        "s-step should beat CoCoA at equal rounds: {gap_sstep} vs {gaps:?}"
    );
    println!("(s-step is exact at any s; CoCoA's extra local work yields diminishing progress)");
}

fn ablation_nystrom(quick: bool) {
    section("Ablation 3 — Nyström-approximated kernel (paper future work)");
    let mut ds = paper_dataset("colon-cancer").unwrap().generate();
    // Unit-scale features → decaying RBF spectrum (see solvers::nystrom).
    {
        let mut a = ds.a.to_dense();
        let n = ds.n() as f64;
        for v in a.data_mut() {
            *v /= n.sqrt();
        }
        ds.a = kcd::sparse::Csr::from_dense(&a);
    }
    let kernel = Kernel::paper_rbf();
    let mut exact = LocalGram::new(ds.a.clone(), kernel);
    let p = SvmParams {
        c: 1.0,
        variant: SvmVariant::L2,
        h: if quick { 200 } else { 1000 },
        seed: 21,
    };
    let mut ledger_exact = Ledger::new();
    let alpha_exact = dcd_sstep(&mut exact, &ds.y, &p, 8, &mut ledger_exact, None);
    let exact_flops = ledger_exact.flops(Phase::KernelCompute);

    let mut t = Table::new(vec![
        "oracle",
        "kernel flops",
        "‖K−K̂‖/‖K‖",
        "‖α−α_exact‖/‖α‖",
    ]);
    t.row(vec![
        "exact".to_string(),
        format!("{exact_flops:.2e}"),
        "0".to_string(),
        "0".to_string(),
    ]);
    let mut devs = Vec::new();
    for l in [8usize, 24, 56] {
        let mut ny = NystromGram::new(&ds.a, kernel, l, 1e-10, 5);
        let kerr = ny.approx_error(&ds.a, kernel);
        let mut ledger = Ledger::new();
        let alpha = dcd_sstep(&mut ny, &ds.y, &p, 8, &mut ledger, None);
        let dev = kcd::dense::rel_err(&alpha, &alpha_exact);
        devs.push(dev);
        t.row(vec![
            format!("nyström l={l}"),
            format!("{:.2e}", ledger.flops(Phase::KernelCompute)),
            format!("{kerr:.2e}"),
            format!("{dev:.2e}"),
        ]);
    }
    print!("{}", t.markdown());
    assert!(
        devs[0] > devs[2],
        "solution error should fall with rank: {devs:?}"
    );
    println!("(higher rank → better solution, more kernel flops — the predicted trade-off)");
}

fn ablation_machine(quick: bool) {
    section("Ablation 4 — machine profile: cloud latency amplifies the s-step win");
    let ds = paper_dataset("duke").unwrap().generate();
    let problem = ProblemSpec::Svm {
        c: 1.0,
        variant: SvmVariant::L1,
    };
    let cfg = SweepConfig {
        p_list: vec![64],
        s_list: vec![8, 32, 128, 256],
        t_list: vec![1],
        pr: 1,
        h: if quick { 64 } else { 512 },
        seed: 31,
        algo: AllreduceAlgo::Rabenseifner,
        measured_limit: 0,
        auto_tune: false,
        ..Default::default()
    };
    let mut speedups = Vec::new();
    for machine in [MachineProfile::cray_ex(), MachineProfile::cloud()] {
        let rows = sweep(&ds, Kernel::paper_rbf(), &problem, &cfg, &machine);
        println!(
            "{:<8} P=64: classical {:.3e}s, best s-step {:.3e}s (s={}) → {:.2}x",
            machine.name,
            rows[0].classical.total_secs(),
            rows[0].best_sstep.total_secs(),
            rows[0].best_s,
            rows[0].speedup()
        );
        speedups.push(rows[0].speedup());
    }
    assert!(
        speedups[1] > speedups[0],
        "worse latency must amplify the win: {speedups:?}"
    );
    println!("(supports the paper's conclusion: federated/cloud settings gain the most)");
}
