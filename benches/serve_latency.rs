//! Serve-side latency/throughput microbenchmarks: batched prediction
//! through the gram engine (`kcd::serve::Predictor`) under the knobs the
//! serve loop exposes — threads, kernel-row cache, batch size — plus the
//! `.kcd` save/load path. The cache case uses a skewed (80/20) request
//! stream, the regime the query-index LRU is built for; all knobs are
//! wall-time-only, so every variant returns the same bits (pinned by
//! `rust/tests/serve_props.rs`) and the interesting number is seconds.
//!
//! Run: `cargo bench --bench serve_latency` (`--quick` for CI sizing).

use kcd::bench_harness::{bench, black_box, quick_mode, section, BenchConfig};
use kcd::costmodel::Ledger;
use kcd::data::{gen_dense_classification, gen_uniform_sparse, SynthParams, Task};
use kcd::kernelfn::Kernel;
use kcd::model::SvmModel;
use kcd::rng::Pcg;
use kcd::serve::{PredictOptions, Predictor};

fn main() {
    let quick = quick_mode();
    let cfg = BenchConfig::default();
    let (m, q) = if quick { (200, 48) } else { (2000, 256) };

    // Model: dense training rows with a dual that keeps ~2/3 of them.
    let ds = gen_dense_classification(m, 32, 0.02, 7);
    let alpha: Vec<f64> = (0..m)
        .map(|i| if i % 3 == 0 { 0.0 } else { ((i * 5) % 11) as f64 / 11.0 })
        .collect();
    let model = SvmModel::from_dual(&ds, &alpha, Kernel::paper_rbf());
    let queries = gen_uniform_sparse(
        SynthParams {
            m: q,
            n: 32,
            density: 0.5,
            seed: 11,
        },
        Task::Classification,
    )
    .a;

    // Skewed stream: 80% of requests hit 20% of the query rows.
    let hot = (q / 5).max(1);
    let mut rng = Pcg::new(0xbeef, 0);
    let stream: Vec<usize> = (0..4 * q)
        .map(|_| {
            if rng.next_f64() < 0.8 {
                rng.gen_range(0, hot)
            } else {
                rng.gen_range(0, queries.nrows())
            }
        })
        .collect();

    section("serve latency — engine-routed batched prediction");
    for threads in [1, 4] {
        for (tag, cache_rows) in [("cold", 0), ("lru-64", 64)] {
            let opts = PredictOptions {
                threads,
                cache_rows,
                batch: 16,
            };
            let r = bench(
                &format!(
                    "predict_stream {} reqs t={threads} {tag} batch=16",
                    stream.len()
                ),
                &cfg,
                || {
                    let mut p = Predictor::new(
                        model.support_vectors(),
                        model.coefficients(),
                        model.kernel(),
                        &queries,
                        &opts,
                    );
                    black_box(p.predict_stream(&stream, opts.batch, &mut Ledger::new()))
                },
            );
            println!(
                "    → {:.0} req/s end to end",
                stream.len() as f64 / r.median()
            );
        }
    }

    section("serve latency — batch-size sweep (t=1, warm cache)");
    for batch in [1, 16, 0] {
        let opts = PredictOptions {
            threads: 1,
            cache_rows: 64,
            batch,
        };
        let mut p = Predictor::new(
            model.support_vectors(),
            model.coefficients(),
            model.kernel(),
            &queries,
            &opts,
        );
        // Prime the cache once so the sweep measures the steady state.
        black_box(p.predict_stream(&stream, opts.batch, &mut Ledger::new()));
        bench(
            &format!("predict_stream batch={batch} (0 = single batch)"),
            &cfg,
            || black_box(p.predict_stream(&stream, opts.batch, &mut Ledger::new())),
        );
    }

    section("serve latency — .kcd save/load roundtrip");
    let path = std::env::temp_dir().join("kcd_serve_latency_bench.kcd");
    bench("save_kcd", &cfg, || model.save_kcd(&path).unwrap());
    let r = bench("load_kcd", &cfg, || {
        black_box(SvmModel::load_kcd(&path).unwrap().n_support())
    });
    let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    println!(
        "    → {bytes} bytes, {:.1} MB/s load",
        bytes as f64 / r.median() / 1e6
    );

    println!("\nserve_latency done ✓");
}
