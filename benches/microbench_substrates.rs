//! Substrate microbenchmarks — the wall-clock harness behind the §Perf
//! optimization pass (EXPERIMENTS.md): dense GEMM, sparse sampled gram,
//! kernel maps, allreduce algorithms, small solves, and PJRT artifact
//! execution.

use kcd::bench_harness::{
    bench, black_box, section, smoke_mode, BenchConfig, BenchLog, BenchRecord,
};
use kcd::comm::{allreduce_sum, run_ranks, AllreduceAlgo};
use kcd::costmodel::Ledger;
use kcd::dense::{gemm_nt, Cholesky, Mat};
use kcd::gram::{CsrProduct, GridStorage, OverlapMode, ProductStage};
use kcd::kernelfn::Kernel;
use kcd::parallel::ParallelProduct;
use kcd::rng::Pcg;
use kcd::solvers::{GramOracle, GridGram, LocalGram};
use kcd::sparse::Csr;

fn rand_mat(rng: &mut Pcg, m: usize, n: usize) -> Mat {
    Mat::from_fn(m, n, |_, _| rng.next_gaussian())
}

fn main() {
    let cfg = BenchConfig::default();
    let mut rng = Pcg::seeded(1);
    // Perf-tracking records for the CI smoke lane (BENCH_SMOKE=1 →
    // BENCH_<date>.json artifact; a no-op for plain `cargo bench`).
    let mut log = BenchLog::new();
    // The smoke lane shrinks the big sparse substrate so the whole
    // suite stays in CI budget; local full runs keep the paper shape.
    let (sg_m, sg_n, sg_stride) = if smoke_mode() {
        (400usize, 1600usize, 12usize)
    } else {
        (2000, 8000, 60)
    };

    section("dense substrate");
    let a = rand_mat(&mut rng, 256, 128);
    let b = rand_mat(&mut rng, 256, 128);
    let mut c = Mat::zeros(256, 256);
    let r = bench("gemm_nt 256x128 · 128x256", &cfg, || {
        gemm_nt(&a, &b, &mut c);
        c.data()[0]
    });
    let flops = 2.0 * 256.0 * 256.0 * 128.0;
    println!("  → {:.2} GF/s", flops / r.median() / 1e9);
    log.push(BenchRecord {
        bench: "gemm_nt".into(),
        config: "m=256 n=128 k=256".into(),
        wall_secs: r.median(),
        flops,
        words: 0.0,
    });

    let spd = {
        let mut g = Mat::zeros(128, 128);
        let x = rand_mat(&mut rng, 128, 128);
        gemm_nt(&x, &x, &mut g);
        for i in 0..128 {
            g[(i, i)] += 128.0;
        }
        g
    };
    let rhs: Vec<f64> = (0..128).map(|_| rng.next_gaussian()).collect();
    bench("cholesky factor+solve 128x128", &cfg, || {
        Cholesky::new(&spd).unwrap().solve(&rhs)
    });

    section("sparse substrate");
    let ds = kcd::data::gen_uniform_sparse(
        kcd::data::SynthParams {
            m: sg_m,
            n: sg_n,
            density: 0.01,
            seed: 3,
        },
        kcd::data::Task::Classification,
    );
    let sample: Vec<usize> = (0..32).map(|i| i * sg_stride).collect();
    let mut q = Mat::zeros(32, sg_m);
    let mut scratch = Vec::new();
    let r = bench(
        &format!("sampled_gram (scatter) 32 rows {sg_m}x{sg_n} @1%"),
        &cfg,
        || {
            ds.a.sampled_gram(&sample, &mut q, &mut scratch);
            q.data()[0]
        },
    );
    let eff_flops = 2.0 * 32.0 * ds.a.nnz() as f64;
    println!("  → {:.2} GF/s effective", eff_flops / r.median() / 1e9);
    log.push(BenchRecord {
        bench: "sampled_gram/scatter".into(),
        config: format!("m={sg_m} n={sg_n} density=0.01 k=32"),
        wall_secs: r.median(),
        flops: eff_flops,
        words: 0.0,
    });
    let at = ds.a.transpose();
    let rt = bench("sampled_gram_t (transpose) same shape", &cfg, || {
        ds.a.sampled_gram_t(&at, &sample, &mut q);
        q.data()[0]
    });
    println!(
        "  → {:.1}x over scatter variant (the sparse-oracle fast path)",
        r.median() / rt.median()
    );
    log.push(BenchRecord {
        bench: "sampled_gram/transpose".into(),
        config: format!("m={sg_m} n={sg_n} density=0.01 k=32"),
        wall_secs: rt.median(),
        flops: eff_flops,
        words: 0.0,
    });

    section(&format!("kernel maps (epilogue over 32x{sg_m} block)"));
    let norms = vec![1.0; sg_m];
    let snorms = vec![1.0; 32];
    for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
        let mut z = q.clone();
        bench(&format!("apply_block {}", kernel.name()), &cfg, || {
            kernel.apply_block(&mut z, &snorms, &norms);
            z.data()[0]
        });
    }

    section("gram oracle end-to-end (rbf, 32 sampled rows)");
    let mut oracle = LocalGram::new(ds.a.clone(), Kernel::paper_rbf());
    let rg = bench(&format!("LocalGram::gram 32x{sg_m}"), &cfg, || {
        let mut ledger = Ledger::new();
        oracle.gram(&sample, &mut q, &mut ledger);
        q.data()[0]
    });
    log.push(BenchRecord {
        bench: "local_gram/rbf".into(),
        config: format!("m={sg_m} n={sg_n} density=0.01 k=32"),
        wall_secs: rg.median(),
        flops: eff_flops,
        words: 0.0,
    });

    section("gram engine row cache (rbf, DCD-like with-replacement stream)");
    // A with-replacement access stream over a working set smaller than m,
    // mimicking DCD coordinate sampling on a skewed active set: repeats
    // are common, so the cache converts kernel recomputes into row copies.
    let stream: Vec<Vec<usize>> = {
        let mut rng = Pcg::seeded(7);
        (0..64)
            .map(|_| (0..8).map(|_| rng.gen_below(200)).collect())
            .collect()
    };
    for cache_rows in [0usize, 64, 256] {
        let mut oracle = LocalGram::with_cache(ds.a.clone(), Kernel::paper_rbf(), cache_rows);
        let mut qq = Mat::zeros(8, sg_m);
        let mut stats = kcd::costmodel::CacheStats::default();
        let r = bench(
            &format!("gram stream 64x8 rows, cache={cache_rows}"),
            &cfg,
            || {
                let mut ledger = Ledger::new();
                for s in &stream {
                    oracle.gram(s, &mut qq, &mut ledger);
                }
                stats = ledger.cache;
                qq.data()[0]
            },
        );
        println!(
            "  → hit rate {:.1}% ({} hits / {} misses), median {:.3}ms",
            100.0 * stats.hit_rate(),
            stats.hits,
            stats.misses,
            r.median() * 1e3
        );
    }

    section("coordinate schedules: uniform vs locality (rbf, cached DCD stream)");
    // The schedule ablation in substrate form: the same cached gram
    // engine driven by the paper's uniform sampler and by the
    // locality-aware schedule (shadow = the engine's cache capacity, so
    // the greedy selection tracks the real LRU exactly). Both streams
    // are seeded and bitwise reproducible; the only difference is which
    // coordinates each call asks for, so the hit-rate gap IS the
    // schedule's win.
    {
        use kcd::schedule::{build_schedule, Schedule, ScheduleKind, ScheduleSpec};
        let (calls, blocks, cache_rows) = (64usize, 8usize, 64usize);
        let nominal_flops = 2.0 * (calls * blocks) as f64 * ds.a.nnz() as f64;
        let mut hit_rates = [f64::NAN; 2];
        for (i, kind) in [ScheduleKind::Uniform, ScheduleKind::LocalityAware]
            .iter()
            .enumerate()
        {
            let mut spec = ScheduleSpec::of(*kind);
            spec.shadow_rows = cache_rows;
            let mut oracle = LocalGram::with_cache(ds.a.clone(), Kernel::paper_rbf(), cache_rows);
            let mut qq = Mat::zeros(blocks, sg_m);
            let mut stats = kcd::costmodel::CacheStats::default();
            let r = bench(
                &format!("gram stream {calls}x{blocks}, cache={cache_rows}, schedule={}", kind.name()),
                &cfg,
                || {
                    let mut sched = build_schedule(&spec, sg_m, 9, 0x5D, &[]);
                    let mut sample = Vec::new();
                    let mut ledger = Ledger::new();
                    for _ in 0..calls {
                        sched.next_call(blocks, 1, &mut sample);
                        oracle.gram(&sample, &mut qq, &mut ledger);
                    }
                    stats = ledger.cache;
                    qq.data()[0]
                },
            );
            hit_rates[i] = stats.hit_rate();
            println!(
                "  → hit rate {:.1}% ({} hits / {} misses), median {:.3}ms",
                100.0 * stats.hit_rate(),
                stats.hits,
                stats.misses,
                r.median() * 1e3
            );
            log.push(BenchRecord {
                bench: format!("schedule/{}", kind.name()),
                config: format!(
                    "m={sg_m} n={sg_n} density=0.01 calls={calls} b={blocks} cache={cache_rows}"
                ),
                wall_secs: r.median(),
                flops: nominal_flops,
                words: 0.0,
            });
        }
        println!(
            "  → locality schedule hit-rate gain: {:+.1} points over uniform",
            100.0 * (hit_rates[1] - hit_rates[0])
        );
    }

    section("threaded product stage (dense gram, sampled-row split)");
    // Dense data where the linear product dominates — the regime the
    // intra-rank threading targets. Every thread count produces the
    // same bits (pinned by tests); only the wall clock moves.
    {
        let dense = kcd::data::gen_dense_classification(1024, 256, 0.0, 21);
        let sample: Vec<usize> = (0..64).map(|i| (i * 13) % 1024).collect();
        let mut q = Mat::zeros(64, 1024);
        let mut t1_median = f64::NAN;
        let mut reference: Option<Vec<f64>> = None;
        for t in [1usize, 2, 4, 8] {
            let mut prod = ParallelProduct::new(CsrProduct::new(dense.a.clone()), t);
            let r = bench(
                &format!("ParallelProduct dense gram 64x1024 t={t}"),
                &cfg,
                || {
                    prod.compute(&sample, &mut q);
                    q.data()[0]
                },
            );
            match &reference {
                None => reference = Some(q.data().to_vec()),
                Some(want) => assert_eq!(q.data(), &want[..], "t={t} bitwise"),
            }
            if t == 1 {
                t1_median = r.median();
            } else {
                println!("  → {:.2}x speedup over t=1", t1_median / r.median());
            }
        }
    }

    section("allreduce algorithms (P=8 threads, w=4096)");
    for algo in [
        AllreduceAlgo::Rabenseifner,
        AllreduceAlgo::RecursiveDoubling,
        AllreduceAlgo::Linear,
    ] {
        let ra = bench(&format!("allreduce {} p=8 w=4096", algo.name()), &cfg, || {
            run_ranks(8, |c| {
                let mut buf = vec![1.0f64; 4096];
                allreduce_sum(c, &mut buf, algo);
                buf[0]
            })
        });
        log.push(BenchRecord {
            bench: format!("allreduce/{}", algo.name()),
            config: "p=8 payload=4096".into(),
            wall_secs: ra.median(),
            flops: 0.0,
            words: 4096.0,
        });
    }

    section("fragment exchange: blocking vs overlapped (sharded 3x2 grid, rbf)");
    // The exchange-overlap substrate in isolation: a sharded grid cell
    // assembles every sampled row through the row-group fragment rings,
    // and `OverlapMode::Exchange` posts those rings under the owned-rows
    // partial product. The blocks, the total traffic and the per-stage
    // traffic are bitwise identical in both modes (pinned below); only
    // the exposed-on-the-wire share and the wall clock move.
    {
        let dense = kcd::data::gen_dense_classification(384, 96, 0.0, 31);
        let gram_stream: Vec<Vec<usize>> = {
            let mut r = Pcg::seeded(17);
            (0..24)
                .map(|_| (0..16).map(|_| r.gen_below(384)).collect())
                .collect()
        };
        let (pr, pc) = (3usize, 2usize);
        let run = |mode: OverlapMode| {
            let shards = dense.shard_cols(pc);
            let stream = gram_stream.clone();
            run_ranks(pr * pc, move |c| {
                let shard = shards[c.rank() % pc].clone();
                let mut o = GridGram::with_opts(
                    shard,
                    Kernel::paper_rbf(),
                    c,
                    AllreduceAlgo::Rabenseifner,
                    pr,
                    pc,
                    4,
                    GridStorage::Sharded,
                    0,
                    1,
                );
                o.set_overlap(mode);
                let mut ledger = Ledger::new();
                let mut q = Mat::zeros(16, 384);
                let mut out = Vec::new();
                for s in &stream {
                    o.gram(s, &mut q, &mut ledger);
                    out.extend_from_slice(q.data());
                }
                (out, o.comm_stats(), o.exch_stats(), ledger.comm_posted)
            })
        };
        let blocking = run(OverlapMode::Off);
        let overlapped = run(OverlapMode::Exchange);
        for ((b_out, b_comm, b_exch, _), (o_out, o_comm, o_exch, posted)) in
            blocking.iter().zip(&overlapped)
        {
            assert_eq!(b_out, o_out, "exchange overlap must be bitwise inert");
            assert_eq!(b_comm, o_comm, "total traffic must be mode-invariant");
            assert_eq!(b_exch, o_exch, "exchange traffic must be mode-invariant");
            assert!(posted.words > 0, "fragment rings must actually be posted");
        }
        let mut medians = [f64::NAN; 2];
        for (i, mode) in [OverlapMode::Off, OverlapMode::Exchange].iter().enumerate() {
            let r = bench(
                &format!("sharded gram stream 24x16, overlap={}", mode.name()),
                &cfg,
                || run(*mode).len(),
            );
            medians[i] = r.median();
        }
        let (_, comm, exch, posted) = &overlapped[0];
        println!(
            "  → exchange words/rank: {} total, {} posted under compute, {} exposed \
             ({:.1}% of exchange, {:.1}% of all comm hidden); wall {:+.1}% vs blocking",
            exch.words,
            posted.words,
            exch.words - posted.words,
            100.0 * posted.words as f64 / exch.words as f64,
            100.0 * posted.words as f64 / comm.words as f64,
            100.0 * (medians[1] - medians[0]) / medians[0]
        );
    }

    section("CSR ops");
    let x: Vec<f64> = (0..sg_n).map(|_| rng.next_gaussian()).collect();
    let mut y = vec![0.0; sg_m];
    let rs = bench(&format!("spmv {sg_m}x{sg_n} @1%"), &cfg, || {
        ds.a.spmv(&x, &mut y);
        y[0]
    });
    log.push(BenchRecord {
        bench: "spmv".into(),
        config: format!("m={sg_m} n={sg_n} density=0.01"),
        wall_secs: rs.median(),
        flops: 2.0 * ds.a.nnz() as f64,
        words: 0.0,
    });
    let rtp = bench(&format!("transpose {sg_m}x{sg_n} @1%"), &cfg, || {
        ds.a.transpose().nnz()
    });
    log.push(BenchRecord {
        bench: "csr_transpose".into(),
        config: format!("m={sg_m} n={sg_n} density=0.01"),
        wall_secs: rtp.median(),
        flops: 0.0,
        words: ds.a.nnz() as f64,
    });
    bench("partition_cols p=16", &cfg, || {
        ds.a.partition_cols(16).len()
    });
    let dense_small = rand_mat(&mut rng, 64, 64);
    bench("csr from_dense/to_dense 64x64", &cfg, || {
        Csr::from_dense(&dense_small).to_dense().data()[0]
    });

    section("PJRT artifact execution (if artifacts built)");
    match kcd::runtime::PjrtRuntime::open(&kcd::runtime::PjrtRuntime::default_dir()) {
        Ok(rt) => {
            let a = rand_mat(&mut rng, 256, 64);
            let mut pjrt = kcd::runtime::PjrtGram::new(rt, &a, Kernel::paper_rbf()).unwrap();
            let sample: Vec<usize> = (0..32).map(|i| i * 7).collect();
            let mut qq = Mat::zeros(32, 256);
            let r = bench("PjrtGram rbf m=256 n=64 k=32", &cfg, || {
                let mut ledger = Ledger::new();
                pjrt.gram(&sample, &mut qq, &mut ledger);
                qq.data()[0]
            });
            let gf = 2.0 * 32.0 * 256.0 * 64.0;
            println!("  → {:.2} GF/s effective (incl. host↔device)", gf / r.median() / 1e9);
            // Native comparison at the same shape.
            let csr = Csr::from_dense(&a);
            let mut native = LocalGram::new(csr, Kernel::paper_rbf());
            bench("LocalGram rbf m=256 n=64 k=32 (native)", &cfg, || {
                let mut ledger = Ledger::new();
                native.gram(&sample, &mut qq, &mut ledger);
                qq.data()[0]
            });
        }
        Err(e) => println!("skipped: {e:#} (run `make artifacts`)"),
    }

    log.write_if_enabled();
    black_box(());
    println!("\nmicrobench done");
}
