//! Figure 5: DCD and s-step DCD strong scaling + breakdown on the
//! news20.binary-like dataset (K-SVM, RBF) under load imbalance.
//!
//! Reproduction target: both methods scale to thousands of processes;
//! s-step DCD hits the load-imbalance scaling limit earlier (its kernel
//! phase uses bandwidth more efficiently, so the imbalanced shard
//! dominates sooner); s-step attains ≈3× at P = 4096 with s = 64 (paper).

use kcd::bench_harness::{quick_mode, section};
use kcd::comm::AllreduceAlgo;
use kcd::coordinator::breakdown::breakdown;
use kcd::coordinator::report::{breakdown_table, scaling_table};
use kcd::coordinator::scaling::{sweep, SweepConfig};
use kcd::coordinator::ProblemSpec;
use kcd::costmodel::MachineProfile;
use kcd::data::paper_dataset;
use kcd::kernelfn::Kernel;
use kcd::solvers::SvmVariant;

fn main() {
    let quick = quick_mode();
    section("Figure 5 — news20.binary K-SVM (RBF) scaling under load imbalance");
    let scale = if quick { 0.1 } else { 0.5 };
    let ds = paper_dataset("news20").unwrap().generate_scaled(scale);
    println!(
        "dataset: {} ({}×{}, {:.4}% dense, imbalance@2048 = {:.2})",
        ds.name,
        ds.m(),
        ds.n(),
        100.0 * ds.a.density(),
        ds.imbalance(2048)
    );
    let machine = MachineProfile::cray_ex();
    let problem = ProblemSpec::Svm {
        c: 1.0,
        variant: SvmVariant::L1,
    };
    let cfg = SweepConfig {
        p_list: vec![128, 256, 512, 1024, 2048, 4096],
        s_list: vec![8, 16, 32, 64, 128],
        t_list: vec![1],
        pr: 1,
        h: if quick { 64 } else { 1024 },
        seed: 5,
        algo: AllreduceAlgo::Rabenseifner,
        measured_limit: 0, // projected engine throughout (P ≥ 128)
        auto_tune: false,
        ..Default::default()
    };
    let rows = sweep(&ds, Kernel::paper_rbf(), &problem, &cfg, &machine);
    print!("{}", scaling_table(&rows).markdown());

    // Scaling-limit check: classical keeps improving longer than s-step
    // (s-step flattens into the imbalance limit earlier).
    let t = |r: &kcd::coordinator::scaling::SweepRow| r.best_sstep.total_secs();
    let classical_gain = rows[0].classical.total_secs() / rows.last().unwrap().classical.total_secs();
    let sstep_gain = t(&rows[0]) / t(rows.last().unwrap());
    println!(
        "\nscaling P=128→4096: classical {classical_gain:.2}x, s-step {sstep_gain:.2}x \
         (s-step flattens earlier under imbalance: {})",
        sstep_gain < classical_gain
    );
    let sp_4096 = rows.last().unwrap().speedup();
    println!("s-step speedup at P = 4096: {sp_4096:.2}x (paper: ≈3x with s = 64)");
    if !quick {
        assert!(sp_4096 > 1.2 && sp_4096 < 8.0, "P=4096 speedup out of regime: {sp_4096}");
    }

    // Breakdown at P = 2048 (the paper's fastest s-step point).
    println!("\n### breakdown at P = 2048");
    let bars = breakdown(
        &ds,
        Kernel::paper_rbf(),
        &problem,
        &[8, 16, 32, 64, 128],
        cfg.h,
        2048,
        1,
        AllreduceAlgo::Rabenseifner,
        &machine,
        0,
        kcd::gram::OverlapMode::Off,
    );
    print!("{}", breakdown_table(&bars).markdown());
    println!("\nFig 5 shape reproduced ✓");
}
