//! Figure 4: running-time breakdown of DCD vs s-step DCD (RBF kernel) at
//! the P with the fastest running time, as s varies.
//!
//! Reproduction target: kernel-compute and allreduce times both fall as s
//! grows (up to the optimum), memreset/gradcorr overheads appear for
//! s > 1 but stay a small fraction, and past the optimal s the allreduce
//! (bandwidth) term grows again — the paper's tuning story.

use kcd::bench_harness::{quick_mode, section};
use kcd::comm::AllreduceAlgo;
use kcd::coordinator::breakdown::breakdown;
use kcd::coordinator::report::breakdown_table;
use kcd::coordinator::ProblemSpec;
use kcd::costmodel::{MachineProfile, Phase};
use kcd::data::paper_dataset;
use kcd::kernelfn::Kernel;
use kcd::solvers::SvmVariant;

fn main() {
    let quick = quick_mode();
    section("Figure 4 — DCD vs s-step DCD runtime breakdown (RBF)");
    let machine = MachineProfile::cray_ex();
    let problem = ProblemSpec::Svm {
        c: 1.0,
        variant: SvmVariant::L1,
    };
    // (dataset, scale, best-P from the Fig-3 sweep regime)
    let cases = [
        ("colon-cancer", 1.0, 32usize),
        ("duke", 1.0, 64),
        ("synthetic", if quick { 0.01 } else { 0.1 }, 512),
    ];
    let s_list = [2usize, 8, 32, 64, 256];
    let h = if quick { 64 } else { 1024 };
    for (name, scale, p) in cases {
        let ds = paper_dataset(name).unwrap().generate_scaled(scale);
        let bars = breakdown(
            &ds,
            Kernel::paper_rbf(),
            &problem,
            &s_list,
            h,
            p,
            1,
            AllreduceAlgo::Rabenseifner,
            &machine,
            0, // projected engine: P here exceeds one box
            kcd::gram::OverlapMode::Off,
        );
        println!("\n### {} at P = {p} (H = {h})", ds.name);
        print!("{}", breakdown_table(&bars).markdown());

        let ar = |i: usize| bars[i].projection.phase_secs(Phase::Allreduce);
        let total = |i: usize| bars[i].projection.total_secs();
        assert!(
            ar(1) < ar(0),
            "{name}: allreduce time must fall from classical to s=2"
        );
        let best = (0..bars.len()).map(total).fold(f64::MAX, f64::min);
        assert!(
            best < total(0),
            "{name}: some s must beat classical"
        );
        // Overheads exist but are not dominant at the optimum.
        let best_i = (0..bars.len()).min_by(|&a, &b| total(a).total_cmp(&total(b))).unwrap();
        if bars[best_i].s > 1 {
            let overhead = bars[best_i].projection.phase_secs(Phase::GradCorr)
                + bars[best_i].projection.phase_secs(Phase::MemReset);
            assert!(
                overhead < 0.5 * total(best_i),
                "{name}: s-step overheads should be a minor fraction at the optimum"
            );
        }
        println!(
            "best s = {} ({:.2}x over classical)",
            bars[best_i].s,
            total(0) / total(best_i)
        );
    }
    println!("\nFig 4 shape reproduced: kernel+allreduce fall with s; overheads stay minor ✓");
}
