//! Figure 7: running-time breakdown of BDCD vs s-step (CA-)BDCD on the
//! news20-like dataset, b = 4, P = 2048, as s varies.
//!
//! Reproduction targets from the paper's §5.2.3 discussion:
//!   * overall s-step benefit reduces to ≈1.14×;
//!   * allreduce (bandwidth) becomes a growing fraction with s — over
//!     45% of runtime at s = 256 / P = 2048, vs much less at P = 128;
//!   * gradient-correction and memory-reset overheads grow with s.

use kcd::bench_harness::{quick_mode, section};
use kcd::comm::AllreduceAlgo;
use kcd::coordinator::breakdown::breakdown;
use kcd::coordinator::report::breakdown_table;
use kcd::coordinator::ProblemSpec;
use kcd::costmodel::{MachineProfile, Phase};
use kcd::data::paper_dataset;
use kcd::kernelfn::Kernel;

fn main() {
    let quick = quick_mode();
    section("Figure 7 — news20.binary K-RR (b = 4) breakdown vs s");
    let scale = if quick { 0.1 } else { 0.5 };
    let ds = paper_dataset("news20").unwrap().generate_scaled(scale);
    let machine = MachineProfile::cray_ex();
    let problem = ProblemSpec::Krr { lambda: 1.0, b: 4 };
    let h = if quick { 64 } else { 512 };
    let s_list = [4usize, 16, 64, 256];

    let frac = |bars: &[kcd::coordinator::breakdown::BreakdownBar], i: usize, ph: Phase| {
        bars[i].projection.phase_secs(ph) / bars[i].projection.total_secs()
    };

    let mut ar_frac_by_p = Vec::new();
    for p in [128usize, 2048] {
        let bars = breakdown(
            &ds,
            Kernel::paper_rbf(),
            &problem,
            &s_list,
            h,
            p,
            1,
            AllreduceAlgo::Rabenseifner,
            &machine,
            0,
            kcd::gram::OverlapMode::Off,
        );
        println!("\n### P = {p}");
        print!("{}", breakdown_table(&bars).markdown());
        let last = bars.len() - 1; // s = 256
        let ar = frac(&bars, last, Phase::Allreduce);
        println!("allreduce fraction at s=256: {:.0}%", ar * 100.0);
        ar_frac_by_p.push(ar);

        if p == 2048 {
            let t: Vec<f64> = bars.iter().map(|b| b.projection.total_secs()).collect();
            let best = t.iter().cloned().fold(f64::MAX, f64::min);
            let speedup = t[0] / best;
            println!("best s-step speedup at P=2048: {speedup:.2}x (paper: 1.14x)");
            if !quick {
                assert!(
                    speedup < 2.5,
                    "bandwidth-bound: win must be modest, got {speedup:.2}"
                );
            }
            // Overheads grow with s.
            let oh = |i: usize| {
                frac(&bars, i, Phase::GradCorr) + frac(&bars, i, Phase::MemReset)
            };
            assert!(oh(last) > oh(1), "gradcorr+memreset share must grow with s");
        }
    }
    assert!(
        ar_frac_by_p[1] > ar_frac_by_p[0],
        "allreduce share at s=256 must be larger at P=2048 than at P=128: {ar_frac_by_p:?}"
    );
    println!("\nFig 7 shape reproduced: allreduce-dominated at large s·P, modest win ✓");
}
