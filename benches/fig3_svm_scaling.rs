//! Figure 3: strong scaling of DCD vs s-step DCD for K-SVM on
//! colon-cancer-, duke-, and synthetic-like datasets, all three kernels,
//! P = 1…512.
//!
//! Reproduction target (paper speedups, best s, best P):
//!   colon-cancer:  linear 3.5× · poly 4.3× · rbf 8.9×
//!   duke:          linear 4.8× · poly 5.4× · rbf 9.8×   (headline)
//!   synthetic:     linear 2.4× · poly 2.4× · rbf 2.0×
//! Shape criteria: rbf ≥ poly ≥ linear on the small dense sets (the
//! kernel map amortizes the latency win), all speedups > 1, the small-m
//! sets gain far more than the bandwidth-heavier synthetic set.

use kcd::bench_harness::{quick_mode, section};
use kcd::comm::AllreduceAlgo;
use kcd::coordinator::report::scaling_table;
use kcd::coordinator::scaling::{sweep, SweepConfig};
use kcd::coordinator::ProblemSpec;
use kcd::costmodel::MachineProfile;
use kcd::data::paper_dataset;
use kcd::kernelfn::Kernel;
use kcd::solvers::SvmVariant;

fn main() {
    let quick = quick_mode();
    section("Figure 3 — K-SVM strong scaling, DCD vs s-step DCD");
    let machine = MachineProfile::cray_ex();
    let problem = ProblemSpec::Svm {
        c: 1.0,
        variant: SvmVariant::L1,
    };
    let cfg = SweepConfig {
        p_list: vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 512],
        s_list: vec![2, 4, 8, 16, 32, 64, 128, 256],
        t_list: vec![1],
        pr: 1,
        h: if quick { 64 } else { 1024 },
        seed: 41,
        algo: AllreduceAlgo::Rabenseifner,
        measured_limit: if quick { 2 } else { 8 },
        auto_tune: false,
        ..Default::default()
    };
    // synthetic runs at full published scale by default (m = 2000 keeps
    // its allreduce messages bandwidth-relevant, the paper's regime);
    // quick mode shrinks it and skips the cross-dataset shape assertions.
    let paper = [
        ("colon-cancer", 1.0, [3.5, 4.3, 8.9]),
        ("duke", 1.0, [4.8, 5.4, 9.8]),
        ("synthetic", if quick { 0.2 } else { 1.0 }, [2.4, 2.4, 2.0]),
    ];
    let kernels = [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()];
    let mut summary: Vec<(String, [f64; 3])> = Vec::new();
    for (name, scale, _) in paper {
        let ds = paper_dataset(name).unwrap().generate_scaled(scale);
        // The full-size synthetic set (16M nnz) is too heavy to thread on
        // one box; its interesting regime is P ≥ 64, which is projected
        // either way (count model cross-validated in `cargo test`).
        let cfg = SweepConfig {
            measured_limit: if name == "synthetic" { 0 } else { cfg.measured_limit },
            ..cfg.clone()
        };
        let mut best = [0.0f64; 3];
        for (ki, kernel) in kernels.iter().enumerate() {
            let rows = sweep(&ds, *kernel, &problem, &cfg, &machine);
            best[ki] = rows.iter().map(|r| r.speedup()).fold(0.0, f64::max);
            if *kernel == Kernel::paper_rbf() {
                println!(
                    "\n### {} — rbf kernel (full sweep; engine: measured ≤ P={}, projected beyond)",
                    ds.name, cfg.measured_limit
                );
                print!("{}", scaling_table(&rows).markdown());
            }
        }
        summary.push((ds.name.clone(), best));
    }
    println!("\n### Max s-step speedup over DCD (ours vs paper)");
    println!("| dataset | linear | poly | rbf | paper (lin/poly/rbf) |");
    println!("|---|---|---|---|---|");
    for ((name, ours), (_, _, paper_nums)) in summary.iter().zip(paper.iter()) {
        println!(
            "| {name} | {:.2}x | {:.2}x | {:.2}x | {:.1}/{:.1}/{:.1} |",
            ours[0], ours[1], ours[2], paper_nums[0], paper_nums[1], paper_nums[2]
        );
    }
    // Shape assertions.
    let colon = &summary[0].1;
    let duke = &summary[1].1;
    let synth = &summary[2].1;
    for (name, s) in &summary {
        assert!(
            s.iter().all(|&v| v > 1.0),
            "{name}: s-step must win somewhere, got {s:?}"
        );
    }
    if !quick {
        assert!(
            duke[2] > synth[2] && colon[2] > synth[2],
            "small-m dense sets must gain more than the synthetic set: \
             duke {duke:?} colon {colon:?} synth {synth:?}"
        );
        // rbf and linear speedups stay in the same ballpark (the paper's
        // absolute factors depend on measured DRAM effects we model with
        // a single blas1 penalty; ordering within ~2x is the shape).
        for (name, s) in [("duke", duke), ("colon", colon)] {
            assert!(
                s[2] > 0.5 * s[0],
                "{name}: rbf speedup should be comparable to linear: {s:?}"
            );
        }
    }
    println!("\nFig 3 shape reproduced: who-wins ordering and magnitudes match the paper ✓");
}
