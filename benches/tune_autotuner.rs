//! Auto-tuner showcase: ranked `(pr, pc, t, s)` plans for the paper's
//! headline regimes on both machine profiles, plus the tuner's own cost
//! (wall-clock per plan — it must stay interactive, since `tune` is a
//! CLI command).
//!
//! The interesting reproduction story: the latency-bound duke regime
//! should tune to a large `s` (the paper's 9.8× case), the
//! bandwidth-bound news20 K-RR regime to a small one (the ~1.14× case),
//! and the cloud profile — two orders of magnitude worse latency —
//! should push every dataset's chosen `s` up.

use kcd::bench_harness::{
    bench, black_box, quick_mode, section, BenchConfig, BenchLog, BenchRecord,
};
use kcd::coordinator::ProblemSpec;
use kcd::costmodel::MachineProfile;
use kcd::data::paper_dataset;
use kcd::kernelfn::Kernel;
use kcd::solvers::SvmVariant;
use kcd::tune::{tune, tune_table, TuneRequest};

fn main() {
    let quick = quick_mode();
    section("Auto-tuned plans — paper regimes × machine profiles");
    let h = if quick { 64 } else { 512 };
    let p = if quick { 64 } else { 512 };
    let cases: [(&str, f64, ProblemSpec); 2] = [
        (
            "duke",
            1.0,
            ProblemSpec::Svm {
                c: 1.0,
                variant: SvmVariant::L1,
            },
        ),
        (
            "news20",
            if quick { 0.05 } else { 0.25 },
            ProblemSpec::Krr { lambda: 1.0, b: 4 },
        ),
    ];
    let machines = [MachineProfile::cray_ex(), MachineProfile::cloud()];
    let mut chosen_s: Vec<(String, usize)> = Vec::new();
    for (name, scale, problem) in &cases {
        let ds = paper_dataset(name).unwrap().generate_scaled(*scale);
        for machine in &machines {
            let mut req = TuneRequest::new(p, h);
            req.s_max = 256;
            let plan = tune(&ds, Kernel::paper_rbf(), problem, &req, machine);
            let best = plan.best();
            println!(
                "\n### {} / {} on {} — P={p}, H={h} ({} candidates)",
                ds.name,
                problem.name(),
                machine.name,
                plan.candidates.len()
            );
            print!("{}", tune_table(&plan, 5).markdown());
            println!("winner: {}", best.cli_hint(problem, h));
            chosen_s.push((format!("{}/{}", ds.name, machine.name), best.s));
        }
    }
    // The cloud profile must never choose a smaller s than cray-ex for
    // the same dataset (α two orders of magnitude worse).
    for pair in chosen_s.chunks(2) {
        let (cray, cloud) = (&pair[0], &pair[1]);
        println!("\nchosen s: {} = {}, {} = {}", cray.0, cray.1, cloud.0, cloud.1);
        assert!(
            cloud.1 >= cray.1,
            "cloud latency must not shrink the tuned s: {chosen_s:?}"
        );
    }

    section("Tuner cost — seconds per full plan (must stay interactive)");
    let ds = paper_dataset("colon-cancer").unwrap().generate_scaled(0.5);
    let problem = ProblemSpec::Svm {
        c: 1.0,
        variant: SvmVariant::L1,
    };
    let cfg = BenchConfig::default();
    let mut log = BenchLog::new();
    for p in [64usize, 512] {
        let req = TuneRequest::new(p, h);
        let machine = MachineProfile::cray_ex();
        let r = bench(&format!("tune colon-cancer P={p}"), &cfg, || {
            let plan = tune(&ds, Kernel::paper_rbf(), &problem, &req, &machine);
            black_box(plan.candidates.len())
        });
        println!("{}", r.line());
        log.push(BenchRecord {
            bench: "tune/full-plan".into(),
            config: format!("dataset=colon-cancer scale=0.5 P={p} H={h}"),
            wall_secs: r.median(),
            flops: 0.0,
            words: 0.0,
        });
    }
    log.write_if_enabled();
}
