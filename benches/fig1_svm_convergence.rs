//! Figure 1: DCD vs s-step DCD convergence (duality gap) for K-SVM-L1 and
//! K-SVM-L2 — duke- and diabetes-like datasets, linear / poly(d=3,c=0) /
//! rbf(σ=1) kernels.
//!
//! Reproduction target: the s-step series (s up to 64) overlays the
//! classical series at every sampled iteration, for every dataset ×
//! kernel × variant — i.e. the s-step method is numerically stable and
//! attains the same solution, the paper's §5.1 claim.

use kcd::bench_harness::{quick_mode, section};
use kcd::coordinator::figures::{max_series_deviation, svm_gap_series};
use kcd::coordinator::report::Table;
use kcd::data::paper_dataset;
use kcd::kernelfn::Kernel;
use kcd::solvers::SvmVariant;

fn main() {
    let quick = quick_mode();
    let h = if quick { 384 } else { 4096 };
    let every = h / 16;
    let s_values = [4usize, 16, 64];

    section("Figure 1 — K-SVM duality-gap convergence, DCD vs s-step DCD");
    println!("H = {h}, gap sampled every {every} iters; overlay = max |gap_s − gap_classical|\n");

    let mut worst: f64 = 0.0;
    for name in ["duke", "diabetes"] {
        let scale = if quick && name == "diabetes" { 0.15 } else { 1.0 };
        let ds = paper_dataset(name).unwrap().generate_scaled(scale);
        let mut t = Table::new(vec![
            "kernel", "variant", "gap@0", "final gap", "overlay s=4", "s=16", "s=64",
        ]);
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            for variant in [SvmVariant::L1, SvmVariant::L2] {
                let classical = svm_gap_series(&ds, kernel, variant, 1.0, h, 1, 21, every);
                let devs: Vec<f64> = s_values
                    .iter()
                    .map(|&s| {
                        let ss = svm_gap_series(&ds, kernel, variant, 1.0, h, s, 21, every);
                        max_series_deviation(&classical, &ss)
                    })
                    .collect();
                worst = worst.max(devs.iter().cloned().fold(0.0, f64::max));
                t.row(vec![
                    kernel.name().to_string(),
                    format!("{variant:?}"),
                    format!("{:.3e}", classical.first().unwrap().1),
                    format!("{:.3e}", classical.last().unwrap().1),
                    format!("{:.1e}", devs[0]),
                    format!("{:.1e}", devs[1]),
                    format!("{:.1e}", devs[2]),
                ]);
            }
        }
        println!("### {} ({}×{})", ds.name, ds.m(), ds.n());
        print!("{}", t.markdown());
        println!();
    }
    println!("worst overlay deviation across all configurations: {worst:.2e}");
    assert!(worst < 1e-7, "Figure 1 reproduction failed: s-step diverged from DCD");
    println!("Fig 1 shape reproduced: s-step DCD ≡ DCD at every sampled iteration ✓");
}
