//! A minimal, offline-friendly subset of the `anyhow` crate API.
//!
//! The build image has no crates.io access, so the workspace vendors the
//! small slice of `anyhow` the codebase actually uses:
//!
//! * [`Error`] — an opaque error value carrying a context chain.
//! * [`Result`] — `Result<T, Error>` alias.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — formatting constructors.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! Display follows the real crate's convention: `{}` shows the outermost
//! message, `{:#}` shows the full `outer: ...: root` chain.

use std::fmt;

/// `Result<T, Error>` — the crate's standard result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error value: an outermost message plus the chain of causes that
/// were attached via [`Context`].
pub struct Error {
    /// `chain[0]` is the outermost message; later entries are causes.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap `self` with an additional layer of context.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(context.to_string());
        chain.extend(self.chain);
        Error { chain }
    }

    fn from_cause<C: fmt::Display, E: fmt::Display>(context: C, cause: E) -> Error {
        // `{:#}` lets a nested `Error` cause render its full chain.
        Error {
            chain: vec![context.to_string(), format!("{cause:#}")],
        }
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Attach context to the error variant of a `Result` (or to a missing
/// `Option`), converting it into [`Error`].
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from_cause(context, e))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from_cause(f(), e))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn nested_context_chains() {
        let inner: Result<()> = Err(anyhow!("root cause"));
        let e = inner.context("middle").unwrap_err().context("outer");
        assert_eq!(format!("{e:#}"), "outer: middle: root cause");
    }

    #[test]
    fn macros_compose() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("12"));
        assert!(f(5).is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(g().is_err());
    }
}
