//! Fixture corpus + self-run coverage.
//!
//! Every directory under `fixtures/bad/` is a miniature source tree
//! whose expected findings are marked in-line with `//~ <rule-id>`
//! trailers; the linter must produce exactly those `(file, line, rule)`
//! triples. Every directory under `fixtures/good/` must lint clean.
//! Finally, the real `rust/src` tree must be diagnostic-free — the
//! self-run that CI's `lint` lane repeats via the binary.

use std::fs;
use std::path::{Path, PathBuf};

type Finding = (String, usize, String);

fn manifest_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn slashes(p: &Path) -> String {
    p.components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

fn case_dirs(kind: &str) -> Vec<PathBuf> {
    let root = manifest_dir().join("fixtures").join(kind);
    let mut dirs: Vec<PathBuf> = fs::read_dir(&root)
        .unwrap_or_else(|e| panic!("{}: {e}", root.display()))
        .map(|e| e.unwrap().path())
        .filter(|p| p.is_dir())
        .collect();
    dirs.sort();
    assert!(!dirs.is_empty(), "no fixture cases under {}", root.display());
    dirs
}

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rs_files(&p, out);
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
}

/// Expected findings for one fixture case, parsed from `//~ <rule-id>`
/// markers. The display path matches `lint_tree`'s joined form.
fn expected_findings(case: &Path) -> Vec<Finding> {
    let case_str = slashes(case);
    let mut files = Vec::new();
    rs_files(case, &mut files);
    let mut out = Vec::new();
    for path in files {
        let rel = slashes(path.strip_prefix(case).unwrap());
        let display = format!("{case_str}/{rel}");
        let src = fs::read_to_string(&path).unwrap();
        for (idx, line) in src.lines().enumerate() {
            let mut rest = line;
            while let Some(pos) = rest.find("//~") {
                rest = &rest[pos + 3..];
                let id = rest.split_whitespace().next().unwrap_or_else(|| {
                    panic!("{display}:{}: bare //~ marker", idx + 1)
                });
                out.push((display.clone(), idx + 1, id.to_string()));
            }
        }
    }
    out.sort();
    out
}

fn actual_findings(case: &Path) -> Vec<Finding> {
    let mut out: Vec<Finding> = detlint::lint_tree(case)
        .unwrap_or_else(|e| panic!("lint_tree({}): {e}", case.display()))
        .into_iter()
        .map(|d| (d.file, d.line, d.rule.id().to_string()))
        .collect();
    out.sort();
    out
}

#[test]
fn bad_fixtures_produce_exactly_the_marked_diagnostics() {
    for case in case_dirs("bad") {
        let expected = expected_findings(&case);
        assert!(
            !expected.is_empty(),
            "bad fixture {} has no //~ markers",
            case.display()
        );
        let actual = actual_findings(&case);
        assert_eq!(
            actual,
            expected,
            "diagnostic mismatch in fixture {}",
            case.display()
        );
    }
}

#[test]
fn good_fixtures_are_clean() {
    for case in case_dirs("good") {
        let actual = actual_findings(&case);
        assert!(
            actual.is_empty(),
            "good fixture {} raised: {actual:?}",
            case.display()
        );
    }
}

#[test]
fn every_rule_has_bad_and_good_coverage() {
    // Keep the corpus honest: each rule id must appear in at least one
    // bad-fixture marker, and the good corpus must exercise the waiver
    // and scoping paths (it is asserted clean above).
    let mut marked: Vec<String> = Vec::new();
    for case in case_dirs("bad") {
        for (_, _, id) in expected_findings(&case) {
            marked.push(id);
        }
    }
    for rule in [
        "map-order",
        "ambient-nondet",
        "phase-coverage",
        "unsafe-safety",
        "ledger-replica",
        "det-ok-syntax",
    ] {
        assert!(
            marked.iter().any(|m| m == rule),
            "no bad fixture covers rule `{rule}`"
        );
    }
}

#[test]
fn self_run_over_rust_src_is_clean() {
    let src = manifest_dir().join("..").join("..").join("rust").join("src");
    let diags = detlint::lint_tree(&src).expect("lint rust/src");
    let rendered: Vec<String> = diags.iter().map(ToString::to_string).collect();
    assert!(
        rendered.is_empty(),
        "determinism contract violations in rust/src:\n{}",
        rendered.join("\n")
    );
}
