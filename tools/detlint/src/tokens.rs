//! Flat token stream over the code side of the line model.
//!
//! Tokens are identifiers/numbers (maximal `[A-Za-z0-9_]+` runs), the
//! two-char sequences `::` and `=>`, and single punctuation chars. String
//! and char literal contents were already blanked by [`crate::lex`], so
//! only their delimiters appear here. Each token remembers its 1-based
//! source line, which is all the rules need for diagnostics.

use crate::lex::LineInfo;

/// One token with its 1-based source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tok {
    /// 1-based line the token starts on.
    pub line: usize,
    /// Token text (identifier, number, `::`, or one punctuation char).
    pub text: String,
}

/// Tokenize the code side of every line.
pub fn tokenize(lines: &[LineInfo]) -> Vec<Tok> {
    let mut toks = Vec::new();
    for (idx, li) in lines.iter().enumerate() {
        let line = idx + 1;
        let chars: Vec<char> = li.code.chars().collect();
        let mut i = 0usize;
        while i < chars.len() {
            let c = chars[i];
            if c.is_whitespace() {
                i += 1;
            } else if c.is_alphanumeric() || c == '_' {
                let start = i;
                while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
                toks.push(Tok {
                    line,
                    text: chars[start..i].iter().collect(),
                });
            } else if c == ':' && chars.get(i + 1) == Some(&':') {
                toks.push(Tok {
                    line,
                    text: "::".to_string(),
                });
                i += 2;
            } else if c == '=' && chars.get(i + 1) == Some(&'>') {
                toks.push(Tok {
                    line,
                    text: "=>".to_string(),
                });
                i += 2;
            } else {
                toks.push(Tok {
                    line,
                    text: c.to_string(),
                });
                i += 1;
            }
        }
    }
    toks
}

/// True if `text` looks like an identifier (starts with a letter or `_`).
pub fn is_ident(text: &str) -> bool {
    text.chars()
        .next()
        .is_some_and(|c| c.is_alphabetic() || c == '_')
}

/// Find the first occurrence of `seq` (by token text) at or after
/// `from`, returning the index of its first token.
pub fn find_seq(toks: &[Tok], seq: &[&str], from: usize) -> Option<usize> {
    if seq.is_empty() || toks.len() < seq.len() {
        return None;
    }
    for i in from..=toks.len() - seq.len() {
        if seq.iter().enumerate().all(|(j, s)| toks[i + j].text == *s) {
            return Some(i);
        }
    }
    None
}

/// Index just past the bracket that closes the opener at `open` (which
/// must be `(`, `[` or `{`). Brackets of all three kinds nest together.
pub fn skip_balanced(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0i64;
    let mut i = open;
    while i < toks.len() {
        match toks[i].text.as_str() {
            "(" | "[" | "{" => depth += 1,
            ")" | "]" | "}" => {
                depth -= 1;
                if depth == 0 {
                    return i + 1;
                }
            }
            _ => {}
        }
        i += 1;
    }
    toks.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lex::split_lines;

    fn toks(src: &str) -> Vec<Tok> {
        tokenize(&split_lines(src))
    }

    fn texts(src: &str) -> Vec<String> {
        toks(src).into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn joins_path_separator() {
        assert_eq!(texts("Instant::now()"), vec!["Instant", "::", "now", "(", ")"]);
    }

    #[test]
    fn splits_single_colon() {
        assert_eq!(texts("m: HashMap"), vec!["m", ":", "HashMap"]);
    }

    #[test]
    fn tracks_line_numbers() {
        let t = toks("a\nb c\n");
        assert_eq!((t[0].line, t[1].line, t[2].line), (1, 2, 2));
    }

    #[test]
    fn string_contents_do_not_tokenize() {
        assert_eq!(texts("f(\"Instant::now\")"), vec!["f", "(", "\"", "\"", ")"]);
    }

    #[test]
    fn find_seq_and_skip_balanced() {
        let t = toks("fn f(a: [u8; 3]) { g(1); }");
        let open = find_seq(&t, &["("], 0).unwrap();
        let close = skip_balanced(&t, open);
        assert_eq!(t[close].text, "{");
        assert!(find_seq(&t, &["fn", "f"], 0).is_some());
        assert!(find_seq(&t, &["fn", "g"], 0).is_none());
    }
}
