//! Comment/string-aware line splitter.
//!
//! The whole analyzer runs on a *line model*: every source line is split
//! into the code that the compiler sees and the comment text attached to
//! it, with string and char literal *contents* blanked out of the code
//! side (so `"HashMap"` in a log message never trips a rule) and comment
//! text preserved (so `// SAFETY:` and `// det-ok:` annotations are
//! findable). The splitter is a small state machine that understands the
//! token forms that matter for not mis-classifying a region:
//!
//! - line comments `//`, nested block comments `/* /* */ */`
//! - string literals with escapes, byte strings `b"…"`
//! - raw strings `r"…"`, `r#"…"#` (arbitrary `#` depth), `br#"…"#`
//! - char literals `'x'`, `'\n'`, `'\''` vs. lifetimes `'a`, `'static`
//!
//! Everything else (macros, cfg, generics) is left to the token layer.

/// One source line, split into compiler-visible code and comment text.
#[derive(Debug, Default, Clone)]
pub struct LineInfo {
    /// Code with string/char contents removed (delimiters kept).
    pub code: String,
    /// Concatenated text of `//` and `/* */` comments on this line.
    pub comment: String,
}

#[derive(Debug, PartialEq, Eq, Clone, Copy)]
enum State {
    Code,
    LineComment,
    /// Nested block comment with the current nesting depth.
    BlockComment(u32),
    /// Normal (escaped) string literal.
    Str,
    /// Raw string literal closed by `"` followed by this many `#`.
    RawStr(u32),
    /// Char literal (escape-aware).
    CharLit,
}

/// Split `src` into per-line code/comment views.
///
/// The output has exactly one entry per source line (including a final
/// line without a trailing newline).
pub fn split_lines(src: &str) -> Vec<LineInfo> {
    let chars: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut cur = LineInfo::default();
    let mut st = State::Code;
    // True when the previous code char continues an identifier, so an
    // `r` in e.g. `var` is never mistaken for a raw-string prefix.
    let mut prev_ident = false;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if st == State::LineComment {
                st = State::Code;
            }
            lines.push(std::mem::take(&mut cur));
            prev_ident = false;
            i += 1;
            continue;
        }
        match st {
            State::Code => {
                let n1 = chars.get(i + 1).copied();
                if c == '/' && n1 == Some('/') {
                    st = State::LineComment;
                    i += 2;
                } else if c == '/' && n1 == Some('*') {
                    st = State::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    st = State::Str;
                    prev_ident = false;
                    i += 1;
                } else if c == '\'' {
                    // `'x'`, `'\n'` are char literals; `'a` in `<'a>` is a
                    // lifetime. A quote is a char literal iff the next
                    // char is an escape, or the char after next closes it.
                    let n2 = chars.get(i + 2).copied();
                    let is_char = match n1 {
                        Some('\\') => true,
                        Some(ch) if ch != '\'' => n2 == Some('\''),
                        _ => false,
                    };
                    cur.code.push('\'');
                    if is_char {
                        st = State::CharLit;
                    }
                    prev_ident = false;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_ident && raw_str_len(&chars, i) > 0 {
                    let (skip, hashes) = raw_str_start(&chars, i);
                    cur.code.push('"');
                    st = State::RawStr(hashes);
                    prev_ident = false;
                    i += skip;
                } else if c == 'b' && !prev_ident && n1 == Some('"') {
                    cur.code.push('"');
                    st = State::Str;
                    prev_ident = false;
                    i += 2;
                } else {
                    cur.code.push(c);
                    prev_ident = c.is_alphanumeric() || c == '_';
                    i += 1;
                }
            }
            State::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                let n1 = chars.get(i + 1).copied();
                if c == '/' && n1 == Some('*') {
                    st = State::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && n1 == Some('/') {
                    st = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // Never skip over a newline (string line-continuation
                    // escape): the `\n` must reach the line accounting.
                    i += if chars.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else if c == '"' {
                    cur.code.push('"');
                    st = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && count_hashes(&chars, i + 1) >= hashes {
                    cur.code.push('"');
                    st = State::Code;
                    i += 1 + hashes as usize;
                } else {
                    i += 1;
                }
            }
            State::CharLit => {
                if c == '\\' {
                    i += if chars.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else if c == '\'' {
                    cur.code.push('\'');
                    st = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    lines.push(cur);
    lines
}

/// If a raw string starts at `i` (`r"`, `r#"`, `br##"`, …), return
/// `(chars to skip past the opening quote, number of hashes)`.
fn raw_str_start(chars: &[char], i: usize) -> (usize, u32) {
    let mut j = i + 1;
    if chars.get(i) == Some(&'b') {
        if chars.get(j) != Some(&'r') {
            return (0, 0);
        }
        j += 1;
    }
    let hashes = count_hashes(chars, j);
    j += hashes as usize;
    if chars.get(j) == Some(&'"') {
        (j + 1 - i, hashes)
    } else {
        (0, 0)
    }
}

/// Length of the raw-string opener at `i`, or 0 if none.
fn raw_str_len(chars: &[char], i: usize) -> usize {
    raw_str_start(chars, i).0
}

fn count_hashes(chars: &[char], mut j: usize) -> u32 {
    let mut n = 0u32;
    while chars.get(j) == Some(&'#') {
        n += 1;
        j += 1;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(src: &str) -> Vec<String> {
        split_lines(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strips_line_comments_but_keeps_text() {
        let lines = split_lines("let x = 1; // SAFETY: fine\n");
        assert_eq!(lines[0].code, "let x = 1; ");
        assert!(lines[0].comment.contains("SAFETY: fine"));
    }

    #[test]
    fn blanks_string_contents() {
        let c = codes("let s = \"HashMap.iter()\";");
        assert_eq!(c[0], "let s = \"\";");
    }

    #[test]
    fn handles_escaped_quotes_in_strings() {
        let c = codes("let s = \"a\\\"b\"; let y = 2;");
        assert_eq!(c[0], "let s = \"\"; let y = 2;");
    }

    #[test]
    fn handles_raw_strings_with_hashes() {
        let c = codes("let s = r#\"multi \" quote Instant::now\"#; let z = 3;");
        assert_eq!(c[0], "let s = \"\"; let z = 3;");
    }

    #[test]
    fn handles_byte_and_raw_byte_strings() {
        let c = codes("let a = b\"x\"; let b2 = br#\"y\"#; done");
        assert_eq!(c[0], "let a = \"\"; let b2 = \"\"; done");
    }

    #[test]
    fn ident_ending_in_r_is_not_raw_string() {
        let c = codes("var\"s\"");
        assert_eq!(c[0], "var\"\"");
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let c = codes("let q = '\"'; fn f<'a>(x: &'a str) { let n = '\\n'; }");
        assert_eq!(c[0], "let q = ''; fn f<'a>(x: &'a str) { let n = ''; }");
    }

    #[test]
    fn nested_block_comments() {
        let lines = split_lines("a /* one /* two */ still */ b");
        assert_eq!(lines[0].code, "a  b");
        assert!(lines[0].comment.contains("one"));
        assert!(lines[0].comment.contains("still"));
    }

    #[test]
    fn multiline_block_comment_spans_lines() {
        let lines = split_lines("a /* x\ny */ b\n");
        assert_eq!(lines[0].code, "a ");
        assert_eq!(lines[1].code, " b");
        assert!(lines[1].comment.contains('y'));
    }

    #[test]
    fn multiline_string_spans_lines() {
        let lines = split_lines("let s = \"one\ntwo\"; let x = 1;\n");
        assert_eq!(lines[0].code, "let s = \"");
        assert_eq!(lines[1].code, "\"; let x = 1;");
    }

    #[test]
    fn one_entry_per_line_including_last() {
        assert_eq!(split_lines("a\nb").len(), 2);
        assert_eq!(split_lines("a\nb\n").len(), 3);
    }
}
