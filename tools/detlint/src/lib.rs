//! detlint — determinism-contract static analysis for the `kcd` tree.
//!
//! The repo's bitwise contracts (sharded ≡ replicated ≡ 1D@`pc`,
//! overlap ≡ blocking, thread/cache/row_block invariance) are enforced
//! at runtime by property suites; this linter machine-checks their
//! *preconditions*, which used to be prose in doc comments:
//!
//! - [`rules::map_order`] — no `HashMap`/`HashSet` iteration in the
//!   deterministic modules (keyed lookups stay free);
//! - [`rules::ambient_nondet`] — clocks, thread identity and ambient
//!   RNG seeding confined to the timing wrappers
//!   (`coordinator/`, `bench_harness/`, `util/`);
//! - [`rules::phase_coverage`] — every `Phase` variant listed in
//!   `Phase::ALL`, labeled, priced by the cost model, and replicated by
//!   the analytic ledgers (cross-file);
//! - [`rules::unsafe_safety`] — every `unsafe` carries `// SAFETY:`;
//! - [`rules::ledger_replica`] — every `CommStats` counter field of
//!   `Ledger` is referenced by the analytic-ledger replicas.
//!
//! A finding on a line that is genuinely order-independent can be
//! waived in place with `// det-ok: <reason>` on the same or the
//! preceding line (not honored by `unsafe-safety` or the cross-file
//! rules). Zero dependencies by design: the analysis is a hand-rolled
//! lexer ([`lex`]) plus a token/line model ([`tokens`]) — see
//! `docs/LINTS.md` for the rule catalog and the model's limits.

pub mod lex;
pub mod rules;
pub mod tokens;

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use lex::LineInfo;
use tokens::{find_seq, Tok};

/// Rule identifiers, used in diagnostics as `[rule-id]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// `HashMap`/`HashSet` iteration in a deterministic module.
    MapOrder,
    /// Clock / thread-identity / ambient-RNG use outside the timing
    /// wrapper modules.
    AmbientNondet,
    /// A `Phase` variant missing from `ALL`, its label match, the cost
    /// model's pricing loops, or the analytic-ledger replicas.
    PhaseCoverage,
    /// An `unsafe` token without a `// SAFETY:` comment.
    UnsafeSafety,
    /// A `Ledger` comm-counter field with no analytic replica.
    LedgerReplica,
    /// A malformed `det-ok` annotation (missing `:` or reason).
    DetOkSyntax,
}

impl Rule {
    /// Stable kebab-case id printed in diagnostics and used by fixture
    /// markers.
    pub fn id(self) -> &'static str {
        match self {
            Rule::MapOrder => "map-order",
            Rule::AmbientNondet => "ambient-nondet",
            Rule::PhaseCoverage => "phase-coverage",
            Rule::UnsafeSafety => "unsafe-safety",
            Rule::LedgerReplica => "ledger-replica",
            Rule::DetOkSyntax => "det-ok-syntax",
        }
    }
}

/// One finding, addressed `file:line`.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Path as shown to the user (scan root joined with the relative
    /// path, forward slashes).
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    /// Which rule fired.
    pub rule: Rule,
    /// Human-readable explanation with the suggested fix.
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file,
            self.line,
            self.rule.id(),
            self.message
        )
    }
}

/// Module classification, derived from the path below the scan root.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModuleClass {
    /// Must be bitwise deterministic: map-order rule applies.
    Deterministic,
    /// Timing wrappers: ambient clocks/thread-ids are allowed here.
    TimingOk,
    /// Everything else: ambient rule applies, map-order does not.
    Other,
}

/// Modules under the bitwise-determinism contract.
const DET_MODULES: &[&str] = &[
    "comm",
    "costmodel",
    "gram",
    "parallel",
    "schedule",
    "serve",
    "solvers",
    "sparse",
    "tune",
];

/// Modules allowed to read clocks and thread identity.
const TIMING_MODULES: &[&str] = &["bench_harness", "coordinator", "util"];

fn classify(rel: &str) -> ModuleClass {
    let mut parts: Vec<&str> = rel.split('/').collect();
    if let Some(last) = parts.pop() {
        parts.push(last.trim_end_matches(".rs"));
    }
    if parts.iter().any(|p| DET_MODULES.contains(p)) {
        ModuleClass::Deterministic
    } else if parts.iter().any(|p| TIMING_MODULES.contains(p)) {
        ModuleClass::TimingOk
    } else {
        ModuleClass::Other
    }
}

/// A lexed, tokenized source file plus the per-line annotation state the
/// rules consult.
pub struct FileCtx {
    /// Display path for diagnostics.
    pub display: String,
    /// Path relative to the scan root (forward slashes).
    pub rel: String,
    /// Module classification of `rel`.
    pub class: ModuleClass,
    /// Per-line code/comment split.
    pub lines: Vec<LineInfo>,
    /// Flat token stream of the code side.
    pub toks: Vec<Tok>,
    /// 1-based line of the first `#[cfg(test)]`; `usize::MAX` if none.
    /// Everything from there to EOF is treated as test code (every file
    /// in this tree keeps its tests in one trailing `mod tests`).
    pub test_start: usize,
    det_ok: Vec<bool>,
}

impl FileCtx {
    /// Lex and tokenize `src`, recording `det-ok` annotations (and
    /// reporting malformed ones into `diags`).
    pub fn build(display: String, rel: String, src: &str, diags: &mut Vec<Diagnostic>) -> Self {
        let class = classify(&rel);
        let lines = lex::split_lines(src);
        let toks = tokens::tokenize(&lines);
        let test_start = find_seq(&toks, &["#", "[", "cfg", "(", "test", ")", "]"], 0)
            .map_or(usize::MAX, |i| toks[i].line);
        let mut det_ok = vec![false; lines.len() + 1];
        for (idx, li) in lines.iter().enumerate() {
            match parse_det_ok(&li.comment) {
                DetOkMark::None => {}
                DetOkMark::Valid => det_ok[idx + 1] = true,
                DetOkMark::Malformed => diags.push(Diagnostic {
                    file: display.clone(),
                    line: idx + 1,
                    rule: Rule::DetOkSyntax,
                    message: "`det-ok` annotation needs a reason: `// det-ok: <why this is \
                              order-independent>`"
                        .to_string(),
                }),
            }
        }
        FileCtx {
            display,
            rel,
            class,
            lines,
            toks,
            test_start,
            det_ok,
        }
    }

    /// True if `line` (1-based) is in the trailing `#[cfg(test)]` region.
    pub fn is_test_line(&self, line: usize) -> bool {
        line >= self.test_start
    }

    /// True if the finding on `line` is waived by a `det-ok:` annotation
    /// on the same or the preceding line.
    pub fn is_waived(&self, line: usize) -> bool {
        self.det_ok.get(line).copied().unwrap_or(false)
            || (line > 0 && self.det_ok.get(line - 1).copied().unwrap_or(false))
    }

    /// Emit a diagnostic against this file.
    pub fn diag(&self, diags: &mut Vec<Diagnostic>, line: usize, rule: Rule, message: String) {
        diags.push(Diagnostic {
            file: self.display.clone(),
            line,
            rule,
            message,
        });
    }
}

enum DetOkMark {
    None,
    Valid,
    Malformed,
}

/// Scan a line's comment text for a `det-ok` annotation. Occurrences
/// that are part of a longer word (`det-ok-syntax` in fixture markers)
/// are ignored, as is anything after a `//~` fixture-expectation marker.
fn parse_det_ok(comment: &str) -> DetOkMark {
    let scan = comment.split("//~").next().unwrap_or("");
    let mut best = DetOkMark::None;
    for (pos, _) in scan.match_indices("det-ok") {
        if pos > 0 {
            let before = scan[..pos].chars().next_back().unwrap();
            if before.is_alphanumeric() || before == '-' || before == '_' {
                continue;
            }
        }
        let rest = &scan[pos + "det-ok".len()..];
        let next = rest.chars().next();
        match next {
            Some(c) if c.is_alphanumeric() || c == '-' || c == '_' => continue,
            Some(':') if !rest[1..].trim().is_empty() => return DetOkMark::Valid,
            _ => best = DetOkMark::Malformed,
        }
    }
    best
}

/// Lint every `.rs` file under `root` (or `root` itself if it is a
/// file). Returns diagnostics sorted by `(file, line, rule)`.
pub fn lint_tree(root: &Path) -> Result<Vec<Diagnostic>, String> {
    let mut paths = Vec::new();
    if root.is_file() {
        paths.push(root.to_path_buf());
    } else if root.is_dir() {
        collect_rs(root, &mut paths)?;
    } else {
        return Err(format!("{}: not a file or directory", root.display()));
    }
    let mut diags = Vec::new();
    let mut ctxs = Vec::new();
    for path in &paths {
        let src = fs::read_to_string(path)
            .map_err(|e| format!("{}: read failed: {e}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path.as_path())
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        let display = if rel.is_empty() {
            slashes(root)
        } else {
            format!("{}/{}", slashes(root).trim_end_matches('/'), rel)
        };
        let rel = if rel.is_empty() {
            root.file_name()
                .map_or_else(|| slashes(root), |n| n.to_string_lossy().into_owned())
        } else {
            rel
        };
        ctxs.push(FileCtx::build(display, rel, &src, &mut diags));
    }
    for f in &ctxs {
        rules::map_order(f, &mut diags);
        rules::ambient_nondet(f, &mut diags);
        rules::unsafe_safety(f, &mut diags);
    }
    rules::phase_coverage(&ctxs, &mut diags);
    rules::ledger_replica(&ctxs, &mut diags);
    diags.sort_by(|a, b| {
        (&a.file, a.line, a.rule, &a.message).cmp(&(&b.file, b.line, b.rule, &b.message))
    });
    Ok(diags)
}

fn slashes(p: &Path) -> String {
    p.components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = Vec::new();
    for e in rd {
        entries.push(e.map_err(|e| format!("{}: {e}", dir.display()))?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_paths() {
        assert_eq!(classify("gram/engine.rs"), ModuleClass::Deterministic);
        assert_eq!(classify("costmodel/mod.rs"), ModuleClass::Deterministic);
        assert_eq!(classify("schedule/mod.rs"), ModuleClass::Deterministic);
        assert_eq!(classify("util/mod.rs"), ModuleClass::TimingOk);
        assert_eq!(classify("coordinator/scaling.rs"), ModuleClass::TimingOk);
        assert_eq!(classify("cli.rs"), ModuleClass::Other);
        assert_eq!(classify("data/mod.rs"), ModuleClass::Other);
    }

    #[test]
    fn det_ok_parsing() {
        assert!(matches!(parse_det_ok(" det-ok: keys are sorted first"), DetOkMark::Valid));
        assert!(matches!(parse_det_ok(" det-ok"), DetOkMark::Malformed));
        assert!(matches!(parse_det_ok(" det-ok: "), DetOkMark::Malformed));
        assert!(matches!(parse_det_ok(" det-ok missing colon"), DetOkMark::Malformed));
        assert!(matches!(parse_det_ok(" nothing here"), DetOkMark::None));
        // Fixture markers and longer words never count as annotations.
        assert!(matches!(parse_det_ok("~ det-ok-syntax"), DetOkMark::None));
        assert!(matches!(parse_det_ok(" x //~ det-ok-syntax"), DetOkMark::None));
    }

    #[test]
    fn test_region_detection() {
        let mut diags = Vec::new();
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {}\n";
        let f = FileCtx::build("x.rs".into(), "x.rs".into(), src, &mut diags);
        assert_eq!(f.test_start, 2);
        assert!(!f.is_test_line(1));
        assert!(f.is_test_line(2));
        assert!(diags.is_empty());
    }
}
