//! CLI: `cargo run -p detlint -- rust/src [more roots…]`.
//!
//! Prints one `path:line: [rule-id] message` diagnostic per finding and
//! exits 1 if any fired, 2 on usage or I/O errors, 0 when clean.

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let roots: Vec<String> = std::env::args().skip(1).collect();
    if roots.is_empty() || roots.iter().any(|a| a == "-h" || a == "--help") {
        eprintln!("usage: detlint <root>…  (e.g. `cargo run -p detlint -- rust/src`)");
        eprintln!("Checks the determinism contract; see docs/LINTS.md for the rules.");
        return ExitCode::from(2);
    }
    let mut total = 0usize;
    for root in &roots {
        match detlint::lint_tree(Path::new(root)) {
            Ok(diags) => {
                for d in &diags {
                    println!("{d}");
                }
                total += diags.len();
            }
            Err(e) => {
                eprintln!("detlint: {e}");
                return ExitCode::from(2);
            }
        }
    }
    if total == 0 {
        eprintln!("detlint: clean ({} root(s))", roots.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("detlint: {total} diagnostic(s)");
        ExitCode::from(1)
    }
}
