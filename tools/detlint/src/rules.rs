//! The five determinism-contract rules.
//!
//! Per-file rules ([`map_order`], [`ambient_nondet`], [`unsafe_safety`])
//! take one [`FileCtx`]; the cross-file rules ([`phase_coverage`],
//! [`ledger_replica`]) take the whole tree because they relate the
//! `Phase`/`Ledger` definitions in `costmodel/` to the analytic-ledger
//! replicas in `coordinator/scaling.rs`.

use std::collections::BTreeSet;

use crate::tokens::{find_seq, is_ident, skip_balanced, Tok};
use crate::{Diagnostic, FileCtx, ModuleClass, Rule};

/// Iteration-order-observing methods on `HashMap`/`HashSet`.
const ITER_METHODS: &[&str] = &[
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "iter",
    "iter_mut",
    "keys",
    "retain",
    "values",
    "values_mut",
];

/// Ambient-nondeterminism sources: `(token sequence, display label)`.
const AMBIENT: &[(&[&str], &str)] = &[
    (&["Instant", "::", "now"], "Instant::now"),
    (&["SystemTime"], "SystemTime"),
    (&["thread", "::", "current"], "thread::current"),
    (&["from_entropy"], "from_entropy"),
    (&["thread_rng"], "thread_rng"),
    (&["rand", "::", "random"], "rand::random"),
    (&["RandomState"], "RandomState"),
];

const MAP_ORDER_HINT: &str = "observes HashMap/HashSet iteration order, which is nondeterministic; walk sorted keys or a Vec index instead, or annotate `// det-ok: <reason>`";
const AMBIENT_HINT: &str = "is ambient nondeterminism; engine code must stay replayable — route timing through `util::PhaseTimer`, move this into coordinator/bench_harness/util, or annotate `// det-ok: <reason>`";
const UNSAFE_MSG: &str = "`unsafe` without a `// SAFETY:` comment (same line or the 5 lines above) stating why the invariants hold";

/// Rule `map-order`: in deterministic modules, flag iteration over any
/// binding whose declared type (or same-statement constructor) is
/// `HashMap`/`HashSet` — `.iter()`-family calls and `for … in map`.
/// Keyed access (`get`/`insert`/`remove`/`contains_key`) stays free.
pub fn map_order(f: &FileCtx, diags: &mut Vec<Diagnostic>) {
    if f.class != ModuleClass::Deterministic {
        return;
    }
    let maps = collect_map_bindings(f);
    if maps.is_empty() {
        return;
    }
    let t = &f.toks;
    for i in 0..t.len() {
        let line = t[i].line;
        if f.is_test_line(line) || f.is_waived(line) {
            continue;
        }
        // `map.iter()` / `self.map.drain(..)` / …
        if t[i].text == "."
            && i + 2 < t.len()
            && t[i + 2].text == "("
            && ITER_METHODS.contains(&t[i + 1].text.as_str())
            && i > 0
            && maps.contains(&t[i - 1].text)
        {
            let msg = format!(
                "`.{}()` on `{}` {MAP_ORDER_HINT}",
                t[i + 1].text,
                t[i - 1].text
            );
            f.diag(diags, line, Rule::MapOrder, msg);
        }
        // `for pat in map { … }` / `for pat in &map { … }`
        if t[i].text == "for" {
            if let Some(name) = for_loop_over(t, i, &maps) {
                let msg = format!("`for … in {name}` {MAP_ORDER_HINT}");
                f.diag(diags, line, Rule::MapOrder, msg);
            }
        }
    }
}

/// If the `for` at `t[i]` loops directly over a binding in `maps`
/// (optionally through `&`/`&mut` or a `self.` prefix), return its name.
fn for_loop_over(t: &[Tok], i: usize, maps: &BTreeSet<String>) -> Option<String> {
    // Find the `in` keyword within the pattern window.
    let limit = (i + 12).min(t.len());
    let in_idx = (i + 1..limit).find(|&j| t[j].text == "in")?;
    // Collect the iterated expression up to the loop body brace.
    let mut expr: Vec<&str> = Vec::new();
    for tok in t.iter().skip(in_idx + 1).take(8) {
        if tok.text == "{" {
            break;
        }
        expr.push(tok.text.as_str());
    }
    while let Some(first) = expr.first() {
        if *first == "&" || *first == "mut" {
            expr.remove(0);
        } else {
            break;
        }
    }
    let name = match expr.as_slice() {
        [id] if is_ident(id) => (*id).to_string(),
        ["self", ".", id] if is_ident(id) => (*id).to_string(),
        _ => return None,
    };
    maps.contains(&name).then_some(name)
}

/// Names bound to a `HashMap`/`HashSet` anywhere in the non-test region:
/// typed bindings (`name: HashMap<…>` — fields, params, typed lets) and
/// same-statement constructors (`let name = HashMap::new()`).
fn collect_map_bindings(f: &FileCtx) -> BTreeSet<String> {
    let t = &f.toks;
    let mut out = BTreeSet::new();
    for i in 0..t.len() {
        if f.is_test_line(t[i].line) {
            break;
        }
        if is_ident(&t[i].text) && i + 2 < t.len() && t[i + 1].text == ":" {
            let mut j = i + 2;
            let mut hops = 0;
            while j < t.len() && hops < 10 {
                let s = t[j].text.as_str();
                if s == "HashMap" || s == "HashSet" {
                    out.insert(t[i].text.clone());
                    break;
                }
                // Skip through references, lifetimes, paths and wrappers:
                // `&'a mut std::collections::HashMap`, `Option<HashMap<…>>`.
                match s {
                    "&" | "mut" | "std" | "::" | "collections" | "Option" | "Box" | "<" => j += 1,
                    "'" => j += 2,
                    _ => break,
                }
                hops += 1;
            }
        }
        if t[i].text == "let" {
            let mut j = i + 1;
            if j < t.len() && t[j].text == "mut" {
                j += 1;
            }
            if j < t.len() && is_ident(&t[j].text) {
                let mut saw_eq = false;
                for k in j + 1..(j + 48).min(t.len()) {
                    match t[k].text.as_str() {
                        ";" => break,
                        "=" => saw_eq = true,
                        "HashMap" | "HashSet" if saw_eq => {
                            out.insert(t[j].text.clone());
                            break;
                        }
                        _ => {}
                    }
                }
            }
        }
    }
    out
}

/// Rule `ambient-nondet`: clocks, thread identity and ambient RNG
/// seeding are confined to `coordinator/`, `bench_harness/`, `util/`.
pub fn ambient_nondet(f: &FileCtx, diags: &mut Vec<Diagnostic>) {
    if f.class == ModuleClass::TimingOk {
        return;
    }
    for (seq, label) in AMBIENT {
        let mut from = 0;
        while let Some(i) = find_seq(&f.toks, seq, from) {
            let line = f.toks[i].line;
            if !f.is_test_line(line) && !f.is_waived(line) {
                let msg = format!("`{label}` {AMBIENT_HINT}");
                f.diag(diags, line, Rule::AmbientNondet, msg);
            }
            from = i + 1;
        }
    }
}

/// Rule `unsafe-safety`: every `unsafe` token needs a `// SAFETY:`
/// comment on its own or one of the five preceding lines. No `det-ok`
/// escape — the safety argument itself is the annotation.
pub fn unsafe_safety(f: &FileCtx, diags: &mut Vec<Diagnostic>) {
    for tok in &f.toks {
        if tok.text != "unsafe" {
            continue;
        }
        let line = tok.line;
        let lo = line.saturating_sub(5).max(1);
        let documented = (lo..=line).any(|ln| {
            f.lines
                .get(ln - 1)
                .is_some_and(|li| li.comment.contains("SAFETY:"))
        });
        if !documented {
            f.diag(diags, line, Rule::UnsafeSafety, UNSAFE_MSG.to_string());
        }
    }
}

/// Rule `phase-coverage` (cross-file): every variant of `enum Phase`
/// must appear in `Phase::ALL` (with a matching declared length), carry
/// a `Phase::V =>` label arm, be priced by `MachineProfile::predict`
/// (and `project`, when present), and be referenced from the analytic
/// ledger file(s).
pub fn phase_coverage(files: &[FileCtx], diags: &mut Vec<Diagnostic>) {
    let Some(pf) = files
        .iter()
        .find(|f| find_seq(&f.toks, &["enum", "Phase", "{"], 0).is_some())
    else {
        return;
    };
    let t = &pf.toks;
    let enum_idx = find_seq(t, &["enum", "Phase", "{"], 0).unwrap();
    let enum_line = t[enum_idx].line;
    let variants = parse_variants(t, enum_idx + 2);

    // `const ALL: [Phase; N] = [ … ];`
    let mut all_entries: Vec<(String, usize)> = Vec::new();
    match find_seq(t, &["const", "ALL", ":", "[", "Phase", ";"], 0) {
        None => {
            let msg = "`Phase` has no `const ALL: [Phase; N]` table — reports and pricing loops cannot enumerate phases".to_string();
            pf.diag(diags, enum_line, Rule::PhaseCoverage, msg);
        }
        Some(ci) => {
            let declared = resolve_const(t, &t[ci + 6].text);
            if let Some(eq) = find_seq(t, &["="], ci) {
                if let Some(open) = find_seq(t, &["["], eq) {
                    let close = skip_balanced(t, open);
                    let mut j = open;
                    while let Some(v) = find_seq(t, &["Phase", "::"], j) {
                        if v + 2 >= close {
                            break;
                        }
                        all_entries.push((t[v + 2].text.clone(), t[v + 2].line));
                        j = v + 2;
                    }
                }
            }
            if let Some(n) = declared {
                if n != variants.len() {
                    let msg = format!(
                        "`Phase::ALL` declares {n} phases but the enum has {} variants",
                        variants.len()
                    );
                    pf.diag(diags, t[ci].line, Rule::PhaseCoverage, msg);
                }
            }
        }
    }
    let all_names: BTreeSet<&str> = all_entries.iter().map(|(n, _)| n.as_str()).collect();
    let variant_names: BTreeSet<&str> = variants.iter().map(|(n, _)| n.as_str()).collect();
    for (name, line) in &all_entries {
        if !variant_names.contains(name.as_str()) {
            let msg = format!("`Phase::ALL` lists `{name}`, which is not a `Phase` variant");
            pf.diag(diags, *line, Rule::PhaseCoverage, msg);
        }
    }

    // Pricing loops: `predict` must exist and enumerate `Phase::ALL`
    // (or every variant explicitly); `project` is checked when present.
    for fname in ["predict", "project"] {
        match fn_body(t, fname) {
            None => {
                if fname == "predict" {
                    let msg = "no `fn predict` found in the `Phase`-defining file — phases are not priced by the cost model".to_string();
                    pf.diag(diags, enum_line, Rule::PhaseCoverage, msg);
                }
            }
            Some((lo, hi)) => {
                let body = &t[lo..hi];
                if find_seq(body, &["Phase", "::", "ALL"], 0).is_none() {
                    for (name, line) in &variants {
                        if find_seq(body, &["Phase", "::", name.as_str()], 0).is_none() {
                            let msg = format!(
                                "`Phase::{name}` is not priced by `fn {fname}` (no `Phase::ALL` loop and no explicit reference)"
                            );
                            pf.diag(diags, *line, Rule::PhaseCoverage, msg);
                        }
                    }
                }
            }
        }
    }

    for (name, line) in &variants {
        if !all_names.contains(name.as_str()) {
            let msg = format!("`Phase::{name}` is missing from `Phase::ALL`");
            pf.diag(diags, *line, Rule::PhaseCoverage, msg);
        }
        if find_seq(t, &["Phase", "::", name.as_str(), "=>"], 0).is_none() {
            let msg = format!(
                "`Phase::{name}` has no `Phase::{name} => …` match arm (label) in the defining file"
            );
            pf.diag(diags, *line, Rule::PhaseCoverage, msg);
        }
    }

    // Analytic replica: each variant must appear in the non-test region
    // of a file defining `analytic_ledger` / `grid_analytic_ledger`.
    let analytic: Vec<&FileCtx> = files.iter().filter(|f| is_analytic_file(f)).collect();
    if analytic.is_empty() {
        return;
    }
    for (name, line) in &variants {
        let replicated = analytic
            .iter()
            .any(|f| has_nontest_seq(f, &["Phase", "::", name.as_str()]));
        if !replicated {
            let msg = format!(
                "`Phase::{name}` is not replicated by the analytic ledgers (`analytic_ledger`/`grid_analytic_ledger`): add its analytic treatment (see `analytic_phase_replica`)"
            );
            pf.diag(diags, *line, Rule::PhaseCoverage, msg);
        }
    }
}

/// Rule `ledger-replica` (cross-file): every `CommStats`-typed field of
/// `struct Ledger` must be referenced (`.field`) in the non-test region
/// of an analytic-ledger file.
pub fn ledger_replica(files: &[FileCtx], diags: &mut Vec<Diagnostic>) {
    let Some(lf) = files
        .iter()
        .find(|f| find_seq(&f.toks, &["struct", "Ledger", "{"], 0).is_some())
    else {
        return;
    };
    let open = find_seq(&lf.toks, &["struct", "Ledger", "{"], 0).unwrap() + 2;
    let fields = parse_comm_fields(&lf.toks, open);
    let analytic: Vec<&FileCtx> = files.iter().filter(|f| is_analytic_file(f)).collect();
    if fields.is_empty() || analytic.is_empty() {
        return;
    }
    for (name, line) in fields {
        let replicated = analytic
            .iter()
            .any(|f| has_nontest_seq(f, &[".", name.as_str()]));
        if !replicated {
            let msg = format!(
                "`Ledger.{name}` is a CommStats counter with no analytic replica: `analytic_ledger`/`grid_analytic_ledger` never assign or read it, so ledger cross-validation cannot cover it"
            );
            lf.diag(diags, line, Rule::LedgerReplica, msg);
        }
    }
}

/// True if `seq` occurs in `f`'s non-test region.
fn has_nontest_seq(f: &FileCtx, seq: &[&str]) -> bool {
    let mut from = 0;
    while let Some(i) = find_seq(&f.toks, seq, from) {
        if !f.is_test_line(f.toks[i].line) {
            return true;
        }
        from = i + 1;
    }
    false
}

/// True for files that *define* the analytic replicas.
fn is_analytic_file(f: &FileCtx) -> bool {
    find_seq(&f.toks, &["fn", "analytic_ledger"], 0).is_some()
        || find_seq(&f.toks, &["fn", "grid_analytic_ledger"], 0).is_some()
}

/// Depth-1 variant names (with lines) of the enum whose `{` is at
/// `open`. Skips `#[…]` attributes and variant payloads.
fn parse_variants(t: &[Tok], open: usize) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let mut depth = 1i64;
    let mut expect = true;
    let mut i = open + 1;
    while i < t.len() && depth > 0 {
        let s = t[i].text.as_str();
        match s {
            "{" | "(" | "[" => {
                depth += 1;
                i += 1;
            }
            "}" | ")" | "]" => {
                depth -= 1;
                i += 1;
            }
            "#" if depth == 1 && i + 1 < t.len() && t[i + 1].text == "[" => {
                i = skip_balanced(t, i + 1);
            }
            "," if depth == 1 => {
                expect = true;
                i += 1;
            }
            _ => {
                if depth == 1 && expect && is_ident(s) {
                    out.push((s.to_string(), t[i].line));
                    expect = false;
                }
                i += 1;
            }
        }
    }
    out
}

/// Depth-1 fields of the struct whose `{` is at `open` whose type
/// mentions `CommStats`.
fn parse_comm_fields(t: &[Tok], open: usize) -> Vec<(String, usize)> {
    let close = skip_balanced(t, open) - 1;
    let mut out = Vec::new();
    let mut depth = 1i64;
    let mut i = open + 1;
    while i < close {
        let s = t[i].text.as_str();
        match s {
            "{" | "(" | "[" => depth += 1,
            "}" | ")" | "]" => depth -= 1,
            _ if depth == 1 && is_ident(s) && i + 1 < close && t[i + 1].text == ":" => {
                let name = s.to_string();
                let line = t[i].line;
                let mut j = i + 2;
                let mut d2 = 0i64;
                let mut has = false;
                while j < close {
                    match t[j].text.as_str() {
                        "{" | "(" | "[" | "<" => d2 += 1,
                        "}" | ")" | "]" | ">" => d2 -= 1,
                        "," if d2 <= 0 => break,
                        "CommStats" => has = true,
                        _ => {}
                    }
                    j += 1;
                }
                if has {
                    out.push((name, line));
                }
                i = j;
                continue;
            }
            _ => {}
        }
        i += 1;
    }
    out
}

/// Token range `(start, end)` of the body of the first `fn name` in `t`.
fn fn_body(t: &[Tok], name: &str) -> Option<(usize, usize)> {
    let fi = find_seq(t, &["fn", name], 0)?;
    let open = find_seq(t, &["{"], fi)?;
    Some((open + 1, skip_balanced(t, open) - 1))
}

/// Resolve an array-length token: a numeric literal, or a `const NAME:
/// usize = <number>;` defined in the same file.
fn resolve_const(t: &[Tok], text: &str) -> Option<usize> {
    if let Ok(n) = text.parse::<usize>() {
        return Some(n);
    }
    let ci = find_seq(t, &["const", text, ":", "usize", "="], 0)?;
    t[ci + 5].text.parse::<usize>().ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::FileCtx;

    fn ctx(rel: &str, src: &str) -> FileCtx {
        let mut diags = Vec::new();
        FileCtx::build(rel.to_string(), rel.to_string(), src, &mut diags)
    }

    fn run_single(rel: &str, src: &str) -> Vec<Diagnostic> {
        let mut diags = Vec::new();
        let f = ctx(rel, src);
        map_order(&f, &mut diags);
        ambient_nondet(&f, &mut diags);
        unsafe_safety(&f, &mut diags);
        diags
    }

    #[test]
    fn map_iteration_flagged_in_det_module() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &HashMap<u32, u32>) -> u32 {\n\
                   \x20   m.values().sum()\n\
                   }\n";
        let d = run_single("gram/x.rs", src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, Rule::MapOrder);
        assert_eq!(d[0].line, 3);
    }

    #[test]
    fn keyed_lookup_is_free() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &HashMap<u32, u32>) -> Option<&u32> {\n\
                   \x20   m.get(&1)\n\
                   }\n";
        assert!(run_single("gram/x.rs", src).is_empty());
    }

    #[test]
    fn for_loop_over_map_flagged() {
        let src = "use std::collections::HashSet;\n\
                   fn f(s: &HashSet<u32>) -> u32 {\n\
                   \x20   let mut acc = 0;\n\
                   \x20   for k in s {\n\
                   \x20       acc ^= *k;\n\
                   \x20   }\n\
                   \x20   acc\n\
                   }\n";
        let d = run_single("solvers/x.rs", src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].line, 4);
    }

    #[test]
    fn det_ok_waives_map_order() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &HashMap<u32, u32>) -> u32 {\n\
                   \x20   // det-ok: summation is order-independent\n\
                   \x20   m.values().sum()\n\
                   }\n";
        assert!(run_single("gram/x.rs", src).is_empty());
    }

    #[test]
    fn vec_iteration_is_free() {
        let src = "fn f(v: &[u32]) -> u32 {\n\
                   \x20   v.iter().sum()\n\
                   }\n";
        assert!(run_single("gram/x.rs", src).is_empty());
    }

    #[test]
    fn untyped_constructor_let_is_tracked() {
        let src = "use std::collections::HashMap;\n\
                   fn f() -> u32 {\n\
                   \x20   let mut m = HashMap::new();\n\
                   \x20   m.insert(1u32, 2u32);\n\
                   \x20   m.keys().sum()\n\
                   }\n";
        let d = run_single("comm/x.rs", src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].line, 5);
    }

    #[test]
    fn non_det_module_map_iteration_is_free() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &HashMap<u32, u32>) -> u32 {\n\
                   \x20   m.values().sum()\n\
                   }\n";
        assert!(run_single("data/x.rs", src).is_empty());
    }

    #[test]
    fn ambient_clock_flagged_outside_timing_modules() {
        let src = "fn f() -> std::time::Instant {\n\
                   \x20   std::time::Instant::now()\n\
                   }\n";
        let d = run_single("sparse/x.rs", src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, Rule::AmbientNondet);
        assert!(run_single("util/x.rs", src).is_empty());
    }

    #[test]
    fn ambient_in_test_region_is_free() {
        let src = "fn f() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   \x20   fn t() { let _ = std::time::Instant::now(); }\n\
                   }\n";
        assert!(run_single("sparse/x.rs", src).is_empty());
    }

    #[test]
    fn unsafe_requires_safety_comment() {
        let bad = "fn f(p: *const u8) -> u8 {\n\
                   \x20   unsafe { *p }\n\
                   }\n";
        let d = run_single("parallel/x.rs", bad);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, Rule::UnsafeSafety);
        let good = "fn f(p: *const u8) -> u8 {\n\
                    \x20   // SAFETY: caller guarantees p is valid.\n\
                    \x20   unsafe { *p }\n\
                    }\n";
        assert!(run_single("parallel/x.rs", good).is_empty());
    }

    const MINI_COSTMODEL: &str = "pub enum Phase {\n\
                                  \x20   A,\n\
                                  \x20   B,\n\
                                  }\n\
                                  impl Phase {\n\
                                  \x20   pub const ALL: [Phase; 2] = [Phase::A, Phase::B];\n\
                                  \x20   pub fn name(&self) -> &'static str {\n\
                                  \x20       match self {\n\
                                  \x20           Phase::A => \"a\",\n\
                                  \x20           Phase::B => \"b\",\n\
                                  \x20       }\n\
                                  \x20   }\n\
                                  }\n\
                                  pub struct CommStats;\n\
                                  pub struct Ledger {\n\
                                  \x20   pub comm: CommStats,\n\
                                  \x20   pub comm_posted: CommStats,\n\
                                  }\n\
                                  impl M {\n\
                                  \x20   pub fn predict(&self) {\n\
                                  \x20       for ph in Phase::ALL {}\n\
                                  \x20   }\n\
                                  }\n";

    #[test]
    fn phase_and_ledger_rules_clean_on_complete_tree() {
        let scaling = "pub fn analytic_ledger() {\n\
                       \x20   l.add(Phase::A, 1.0);\n\
                       \x20   l.add(Phase::B, 1.0);\n\
                       \x20   l.comm = x;\n\
                       \x20   l.comm_posted = y;\n\
                       }\n";
        let files = vec![
            ctx("costmodel/mod.rs", MINI_COSTMODEL),
            ctx("coordinator/scaling.rs", scaling),
        ];
        let mut diags = Vec::new();
        phase_coverage(&files, &mut diags);
        ledger_replica(&files, &mut diags);
        assert!(diags.is_empty(), "unexpected: {diags:?}");
    }

    #[test]
    fn missing_replicas_are_flagged() {
        // No Phase::B reference and no comm_posted assignment.
        let scaling = "pub fn analytic_ledger() {\n\
                       \x20   l.add(Phase::A, 1.0);\n\
                       \x20   l.comm = x;\n\
                       }\n";
        let files = vec![
            ctx("costmodel/mod.rs", MINI_COSTMODEL),
            ctx("coordinator/scaling.rs", scaling),
        ];
        let mut diags = Vec::new();
        phase_coverage(&files, &mut diags);
        ledger_replica(&files, &mut diags);
        assert_eq!(diags.len(), 2, "{diags:?}");
        let phase_hit = diags
            .iter()
            .any(|d| d.rule == Rule::PhaseCoverage && d.message.contains("Phase::B"));
        let ledger_hit = diags
            .iter()
            .any(|d| d.rule == Rule::LedgerReplica && d.message.contains("comm_posted"));
        assert!(phase_hit && ledger_hit, "{diags:?}");
    }

    #[test]
    fn variant_missing_from_all_is_flagged() {
        let src = "pub enum Phase { A, B }\n\
                   impl Phase {\n\
                   \x20   pub const ALL: [Phase; 1] = [Phase::A];\n\
                   \x20   pub fn name(&self) -> &'static str {\n\
                   \x20       match self { Phase::A => \"a\", Phase::B => \"b\" }\n\
                   \x20   }\n\
                   \x20   pub fn predict(&self) { for ph in Phase::ALL {} }\n\
                   }\n";
        let files = vec![ctx("costmodel/mod.rs", src)];
        let mut diags = Vec::new();
        phase_coverage(&files, &mut diags);
        let missing = diags
            .iter()
            .any(|d| d.message.contains("missing from `Phase::ALL`"));
        let count = diags.iter().any(|d| d.message.contains("declares 1 phases"));
        assert!(missing && count, "{diags:?}");
    }

    #[test]
    fn nphase_const_indirection_resolves() {
        let src = "pub enum Phase { A, B }\n\
                   const NPHASE: usize = 2;\n\
                   impl Phase {\n\
                   \x20   pub const ALL: [Phase; NPHASE] = [Phase::A, Phase::B];\n\
                   \x20   pub fn name(&self) -> &'static str {\n\
                   \x20       match self { Phase::A => \"a\", Phase::B => \"b\" }\n\
                   \x20   }\n\
                   \x20   pub fn predict(&self) { for ph in Phase::ALL {} }\n\
                   }\n";
        let files = vec![ctx("costmodel/mod.rs", src)];
        let mut diags = Vec::new();
        phase_coverage(&files, &mut diags);
        assert!(diags.is_empty(), "unexpected: {diags:?}");
    }
}
