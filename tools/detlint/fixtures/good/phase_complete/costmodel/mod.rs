// Good fixture: a complete Phase/Ledger pair — every variant in ALL,
// labeled, priced, and replicated; every CommStats field replicated.
pub enum Phase {
    Compute,
    Slack,
}

impl Phase {
    pub const ALL: [Phase; 2] = [Phase::Compute, Phase::Slack];

    pub fn name(&self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::Slack => "slack",
        }
    }
}

pub struct CommStats {
    pub words: f64,
}

pub struct Ledger {
    pub comm: CommStats,
    pub comm_posted: CommStats,
    pub mem_words: u64,
}

pub struct MachineProfile;

impl MachineProfile {
    pub fn predict(&self) -> f64 {
        let mut acc = 0.0;
        for ph in Phase::ALL {
            acc += ph as usize as f64;
        }
        acc
    }

    pub fn project(&self) -> f64 {
        let mut acc = 0.0;
        for ph in Phase::ALL {
            acc += 2.0 * (ph as usize as f64);
        }
        acc
    }
}
