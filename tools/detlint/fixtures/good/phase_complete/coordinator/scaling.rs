// Analytic side of the phase_complete fixture: both variants and both
// CommStats counters are replicated in non-test code.
pub fn analytic_ledger(l: &mut Ledger) {
    let _ = Phase::Compute;
    l.comm.words = 1.0;
}

pub fn grid_analytic_ledger(l: &mut Ledger) {
    let _ = Phase::Slack;
    l.comm_posted.words = 2.0;
}
