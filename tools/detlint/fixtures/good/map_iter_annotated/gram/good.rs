// Good fixture: map iteration waived by a reasoned waiver annotation,
// in both same-line and preceding-line positions.
use std::collections::HashMap;

pub fn count(m: &HashMap<u32, u32>) -> usize {
    m.keys().count() // det-ok: count() is order-independent
}

pub fn total(m: &HashMap<u32, u32>) -> u32 {
    // det-ok: commutative sum — order cannot affect the result
    m.values().sum()
}
