// Good fixture: the calibration *sampler* lives in bench_harness/ —
// the allowlisted timing module — so wall-clock reads are its job
// (the paired bad fixture flags the same read in tune/calibrate).
use std::time::Instant;

pub fn sample_once<F: FnOnce()>(f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}
