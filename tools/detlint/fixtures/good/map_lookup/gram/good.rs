// Good fixture: keyed map access and Vec iteration in a deterministic
// module are both free.
use std::collections::HashMap;

pub fn gather(pos: &HashMap<usize, usize>, order: &[usize], vals: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(order.len());
    for &row in order {
        if let Some(&slot) = pos.get(&row) {
            out.push(vals[slot]);
        }
    }
    out
}

pub fn fill(pos: &mut HashMap<usize, usize>, order: &[usize]) {
    pos.clear();
    for (slot, &row) in order.iter().enumerate() {
        pos.insert(row, slot);
    }
}
