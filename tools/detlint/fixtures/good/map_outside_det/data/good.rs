// Good fixture: data/ is not a deterministic module, so map iteration
// is out of the map-order rule's scope.
use std::collections::HashMap;

pub fn label_histogram(labels: &[i32]) -> Vec<(i32, usize)> {
    let mut h: HashMap<i32, usize> = HashMap::new();
    for &l in labels {
        *h.entry(l).or_insert(0) += 1;
    }
    let mut out: Vec<(i32, usize)> = h.into_iter().collect();
    out.sort_unstable();
    out
}
