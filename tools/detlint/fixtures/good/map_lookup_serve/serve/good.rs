// Good fixture: the serving core dedups requests through keyed map
// access only and iterates the ordered stream — no map-order dependence.
use std::collections::HashMap;

pub fn dedup_stream(reqs: &[u64]) -> (Vec<u64>, Vec<usize>) {
    let mut seen: HashMap<u64, usize> = HashMap::new();
    let mut unique = Vec::new();
    let mut stream = Vec::with_capacity(reqs.len());
    for &k in reqs {
        let row = *seen.entry(k).or_insert_with(|| {
            unique.push(k);
            unique.len() - 1
        });
        stream.push(row);
    }
    (unique, stream)
}

pub fn replay(stream: &[usize], scores: &[f64]) -> Vec<f64> {
    stream.iter().map(|&row| scores[row]).collect()
}
