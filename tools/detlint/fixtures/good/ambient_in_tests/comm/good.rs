// Good fixture: a clock in the trailing test module of a deterministic
// module is fine — tests may time themselves.
pub fn double(x: u64) -> u64 {
    x * 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doubles_quickly() {
        let t0 = std::time::Instant::now();
        assert_eq!(double(21), 42);
        assert!(t0.elapsed().as_secs() < 60);
    }
}
