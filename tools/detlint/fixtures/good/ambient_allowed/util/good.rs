// Good fixture: util/ is a timing-wrapper module; clocks are its job.
use std::time::Instant;

pub fn time_it<F: FnOnce()>(f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}
