// Fixture: `.keys()` and a bare `for … in set` in a deterministic module.
use std::collections::{HashMap, HashSet};

pub fn xor_members(s: &HashSet<u32>) -> u32 {
    let mut acc = 0;
    for k in s { //~ map-order
        acc ^= *k;
    }
    acc
}

pub fn min_key(m: &HashMap<u32, u32>) -> u32 {
    m.keys().min().copied().unwrap_or(0) //~ map-order
}
