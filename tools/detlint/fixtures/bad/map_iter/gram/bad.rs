// Fixture: HashMap iteration in a deterministic module (gram/).
use std::collections::HashMap;

pub fn sum_values(m: &HashMap<usize, f64>) -> f64 {
    let mut total = 0.0;
    for (_, v) in m.iter() { //~ map-order
        total += v;
    }
    total
}

pub fn lookup(m: &HashMap<usize, f64>, k: usize) -> Option<f64> {
    // Keyed access stays free.
    m.get(&k).copied()
}
