// Fixture: `unsafe` with no SAFETY comment anywhere near it.
pub fn first_byte(v: &[u8]) -> u8 {
    assert!(!v.is_empty());
    unsafe { *v.as_ptr() } //~ unsafe-safety
}
