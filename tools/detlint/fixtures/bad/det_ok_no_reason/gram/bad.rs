// Fixture: a reason-less waiver annotation does not waive anything and
// is itself flagged.
use std::collections::HashMap;

pub fn total(m: &HashMap<u32, u32>) -> u32 {
    // det-ok //~ det-ok-syntax
    m.values().sum() //~ map-order
}
