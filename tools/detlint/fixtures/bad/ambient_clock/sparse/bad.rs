// Fixture: wall-clock read inside an engine module.
pub fn elapsed() -> f64 {
    let t0 = std::time::Instant::now(); //~ ambient-nondet
    t0.elapsed().as_secs_f64()
}
