// Fixture: draining an untyped-constructor map in a deterministic module.
use std::collections::HashMap;

pub fn flush() -> Vec<(u64, u64)> {
    let mut pending = HashMap::new();
    pending.insert(1u64, 2u64);
    let mut out = Vec::new();
    for (k, v) in pending.drain() { //~ map-order
        out.push((k, v));
    }
    out
}
