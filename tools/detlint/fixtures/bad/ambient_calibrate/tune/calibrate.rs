// Fixture: the calibration *fit* (tune/calibrate) is pure arithmetic
// on already-collected timings; reading a clock here breaks the
// division of labor — sampling belongs in bench_harness/calibrate.
pub fn fit_with_clock(counts: &[f64]) -> f64 {
    let t0 = std::time::Instant::now(); //~ ambient-nondet
    counts.iter().sum::<f64>() / t0.elapsed().as_secs_f64()
}
