// Fixture: `Slack` is a Phase variant but never made it into ALL (and
// the declared length went stale with it).
pub enum Phase {
    Compute,
    Slack, //~ phase-coverage
}

impl Phase {
    pub const ALL: [Phase; 1] = [Phase::Compute]; //~ phase-coverage

    pub fn name(&self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::Slack => "slack",
        }
    }
}

pub struct MachineProfile;

impl MachineProfile {
    pub fn predict(&self) -> f64 {
        let mut acc = 0.0;
        for ph in Phase::ALL {
            acc += ph as usize as f64;
        }
        acc
    }
}
