// Analytic side of the phase_missing_all fixture: both variants are
// replicated here, so only the ALL-table findings fire.
pub fn analytic_ledger() -> f64 {
    let a = Phase::Compute as usize as f64;
    let b = Phase::Slack as usize as f64;
    a + b
}
