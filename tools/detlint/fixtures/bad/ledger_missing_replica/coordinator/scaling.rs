// Analytic side of the ledger_missing_replica fixture: only `comm` is
// replicated (`mem_words` is not a CommStats field, so it is exempt).
pub fn grid_analytic_ledger(l: &mut Ledger) {
    l.comm.words = 1.0;
}
