// Fixture: `comm_row` is a CommStats counter the analytic ledger never
// touches, so cross-validation cannot cover it.
pub struct CommStats {
    pub words: f64,
}

pub struct Ledger {
    pub comm: CommStats,
    pub comm_row: CommStats, //~ ledger-replica
    pub mem_words: u64,
}
