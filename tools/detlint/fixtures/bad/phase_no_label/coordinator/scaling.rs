// Analytic side of the phase_no_label fixture: complete, so only the
// missing label fires.
pub fn analytic_ledger() -> f64 {
    let a = Phase::Compute as usize as f64;
    let b = Phase::Slack as usize as f64;
    a + b
}
