// Fixture: a wildcard arm swallowed `Slack`'s label — compiles fine,
// but the report would print the wrong tag.
pub enum Phase {
    Compute,
    Slack, //~ phase-coverage
}

impl Phase {
    pub const ALL: [Phase; 2] = [Phase::Compute, Phase::Slack];

    pub fn name(&self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            _ => "unknown",
        }
    }
}

pub struct MachineProfile;

impl MachineProfile {
    pub fn predict(&self) -> f64 {
        let mut acc = 0.0;
        for ph in Phase::ALL {
            acc += ph as usize as f64;
        }
        acc
    }
}
