// Fixture: system time and thread identity in a deterministic module.
pub fn tag() -> u64 {
    let _since = std::time::SystemTime::now(); //~ ambient-nondet
    std::thread::current().id().as_u64().get() //~ ambient-nondet
}
