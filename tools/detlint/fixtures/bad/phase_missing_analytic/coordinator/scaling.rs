// Analytic side of the phase_missing_analytic fixture: `Phase::Slack`
// is only mentioned in the test region, which does not count.
pub fn analytic_ledger() -> f64 {
    Phase::Compute as usize as f64
}

#[cfg(test)]
mod tests {
    #[test]
    fn slack_reference_in_tests_does_not_count() {
        let _ = Phase::Slack;
    }
}
