// Fixture: the enum side is complete; the analytic ledger forgot to
// replicate `Slack` (see coordinator/scaling.rs).
pub enum Phase {
    Compute,
    Slack, //~ phase-coverage
}

impl Phase {
    pub const ALL: [Phase; 2] = [Phase::Compute, Phase::Slack];

    pub fn name(&self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::Slack => "slack",
        }
    }
}

pub struct MachineProfile;

impl MachineProfile {
    pub fn predict(&self) -> f64 {
        let mut acc = 0.0;
        for ph in Phase::ALL {
            acc += ph as usize as f64;
        }
        acc
    }
}
