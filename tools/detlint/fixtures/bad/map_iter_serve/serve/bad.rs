// Fixture: HashMap iteration in the serving core (serve/ is a
// deterministic module — response bits must not depend on map order).
use std::collections::HashMap;

pub fn drain_responses(pending: &HashMap<usize, f64>) -> Vec<f64> {
    let mut out = Vec::with_capacity(pending.len());
    for (_, score) in pending.iter() { //~ map-order
        out.push(*score);
    }
    out
}

pub fn score_of(pending: &HashMap<usize, f64>, req: usize) -> Option<f64> {
    // Keyed access stays free.
    pending.get(&req).copied()
}
