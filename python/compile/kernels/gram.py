"""L1: Pallas kernel for the sampled gram block — the compute hot-spot.

Every iteration of (s-step) DCD/BDCD forms ``Q = K(A, A_S)``: ``sb`` rows
of the kernel matrix, i.e. a tall-skinny GEMM ``S @ Aᵀ`` followed by a
pointwise kernel map (identity / polynomial / RBF). The paper blocks this
computation explicitly because computing ``s`` rows at once has far better
memory-bandwidth utilization than one row at a time (its Figure 4
observation); on a TPU the same insight maps onto MXU tiling: ``A`` tiles
stream HBM→VMEM once per sampled-block column, and the nonlinear epilogue
is fused so each output tile is written exactly once (see DESIGN.md
§Hardware-Adaptation).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the interpreter to plain
HLO. The structure (BlockSpec schedule, fused epilogue) is what a real TPU
lowering would use; VMEM/MXU estimates live in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output tile sizes. 128 is the MXU native dimension; the sampled-row tile
# adapts to small s·b. With (bk, bm, n) = (128, 256, 128) in f32 the VMEM
# working set is s_tile + x_tile + o_tile ≈ (128·128 + 256·128 + 128·256)·4B
# ≈ 320 KiB — comfortably under the ~16 MiB VMEM budget, leaving room for
# double buffering.
DEFAULT_BM = 256
DEFAULT_BK = 128


def _epilogue(kind: str, z, sn, xn, *, c: float, d: int, sigma: float):
    """Fused kernel map applied to a gram tile ``z[r, i] = <s_r, a_i>``.

    ``sn``/``xn`` are squared row norms of the sampled/full tiles (RBF
    only). All branches are traced statically — ``kind`` is a Python
    constant per compiled artifact.
    """
    if kind == "linear":
        return z
    if kind == "poly":
        return (c + z) ** d
    if kind == "rbf":
        d2 = jnp.maximum(sn[:, None] + xn[None, :] - 2.0 * z, 0.0)
        return jnp.exp(-sigma * d2)
    raise ValueError(f"unknown kernel kind: {kind}")


def _gram_kernel(s_ref, x_ref, o_ref, *, kind: str, c: float, d: int, sigma: float):
    """Pallas body: one (bk × bm) output tile.

    ``s_ref``: (bk, n) sampled rows; ``x_ref``: (bm, n) data rows. The
    contraction runs over the full feature dimension in one MXU pass
    (n ≤ a few hundred for the AOT shapes; larger n would add a third
    grid axis with an accumulator).
    """
    s = s_ref[...]
    x = x_ref[...]
    z = jax.lax.dot_general(
        s,
        x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if kind == "rbf":
        sn = jnp.sum(s * s, axis=1)
        xn = jnp.sum(x * x, axis=1)
    else:
        sn = xn = None
    o_ref[...] = _epilogue(kind, z, sn, xn, c=c, d=d, sigma=sigma).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("kind", "c", "d", "sigma", "bk", "bm", "interpret")
)
def gram_block(
    a,
    s,
    *,
    kind: str = "linear",
    c: float = 0.0,
    d: int = 3,
    sigma: float = 1.0,
    bk: int | None = None,
    bm: int | None = None,
    interpret: bool = True,
):
    """Sampled kernel block ``Q[r, i] = K(s_r, a_i)`` of shape ``(k, m)``.

    Args:
      a: ``(m, n)`` data matrix.
      s: ``(k, n)`` sampled rows (``k = s·b`` in the s-step methods).
      kind: ``linear`` | ``poly`` | ``rbf`` (static).
      c, d: polynomial parameters ``(c + z)^d`` (static).
      sigma: RBF bandwidth (static).
      bk, bm: output tile sizes (default: adapt to the problem).
      interpret: must stay True on CPU PJRT (Mosaic is TPU-only).
    """
    m, n = a.shape
    k, n2 = s.shape
    if n != n2:
        raise ValueError(f"feature dims differ: {n} vs {n2}")
    bk = min(bk or DEFAULT_BK, k)
    bm = min(bm or DEFAULT_BM, m)
    grid = (pl.cdiv(k, bk), pl.cdiv(m, bm))
    kernel = functools.partial(_gram_kernel, kind=kind, c=c, d=d, sigma=sigma)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, n), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bk, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, m), jnp.float32),
        interpret=interpret,
    )(s, a)
