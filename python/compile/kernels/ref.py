"""Pure-jnp oracle for the gram-block kernel (correctness reference).

Everything here is the direct mathematical definition with no tiling or
fusion — the Pallas kernel and the Rust native path are both validated
against it (pytest on the Python side; the Rust side cross-checks through
the PJRT runtime integration tests).
"""

from __future__ import annotations

import jax.numpy as jnp


def gram_block_ref(a, s, *, kind="linear", c=0.0, d=3, sigma=1.0):
    """``Q[r, i] = K(s_r, a_i)`` of shape ``(k, m)`` — definitional."""
    z = s @ a.T
    if kind == "linear":
        return z
    if kind == "poly":
        return (c + z) ** d
    if kind == "rbf":
        # Direct pairwise distances (no dot-product expansion) so the
        # oracle is an independent formulation from the kernel under test.
        diff = s[:, None, :] - a[None, :, :]
        d2 = jnp.sum(diff * diff, axis=-1)
        return jnp.exp(-sigma * d2)
    raise ValueError(f"unknown kernel kind: {kind}")
