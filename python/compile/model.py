"""L2: the JAX compute graph AOT-compiled for the Rust runtime.

The (s-step) coordinate-descent hot-spot is the sampled kernel block
``Q = K(A, A_S)``. This module wraps the L1 Pallas kernel
(:mod:`compile.kernels.gram`) into the exact function signatures the Rust
coordinator executes through PJRT:

  ``gram_program(kind, params)(a, s) -> (q,)``

with ``a: (m, n) f32`` (the data shard), ``s: (k, n) f32`` (the gathered
sampled rows, ``k = s·b``), returning the ``(k, m) f32`` kernel block.
Row norms for the RBF map are computed in-graph (they fuse into the same
HLO module), so the runtime ships exactly two buffers per call.

Python never runs at request time: :mod:`compile.aot` lowers these
functions once per (kind, shape) to ``artifacts/*.hlo.txt``.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels.gram import gram_block

#: Shapes lowered by `make artifacts`: (m, n) data shapes × k sampled rows.
#: (m, n) = (256, 64) covers tests/examples at small scale; (2048, 128) is
#: the e2e driver's dense workload. k spans the s·b values the benches use.
AOT_DATA_SHAPES = ((256, 64), (2048, 128))
AOT_SAMPLE_COUNTS = (1, 8, 32, 64, 256)
AOT_KINDS = ("linear", "poly", "rbf")

#: Paper-default kernel parameters (Figure 1: poly d=3 c=0, rbf σ=1).
DEFAULT_PARAMS = {"c": 0.0, "d": 3, "sigma": 1.0}


def gram_program(kind: str, **params) -> Callable:
    """The jitted L2 function for one kernel family.

    Returns ``f(a, s) -> (q,)`` — a 1-tuple, matching the
    ``return_tuple=True`` convention the Rust loader unwraps with
    ``to_tuple1``.
    """
    p = dict(DEFAULT_PARAMS)
    p.update(params)

    @jax.jit
    def f(a, s):
        q = gram_block(
            a,
            s,
            kind=kind,
            c=float(p["c"]),
            d=int(p["d"]),
            sigma=float(p["sigma"]),
            interpret=True,
        )
        return (q,)

    return f


@functools.lru_cache(maxsize=None)
def _cached_program(kind: str) -> Callable:
    return gram_program(kind)


def gram_apply(kind: str, a, s):
    """Convenience eager evaluation (tests, notebooks)."""
    return _cached_program(kind)(a, s)[0]


def artifact_name(kind: str, m: int, n: int, k: int) -> str:
    """Canonical artifact stem shared with the Rust runtime manifest."""
    return f"gram_{kind}_m{m}_n{n}_k{k}"


def example_args(m: int, n: int, k: int):
    """ShapeDtypeStructs for lowering."""
    return (
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
    )
