"""AOT lowering: JAX/Pallas → HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Writes one ``<name>.hlo.txt`` per (kernel kind, data shape, sample count)
plus ``manifest.json`` describing every artifact (consumed by
``rust/src/runtime``). Incremental: artifacts whose sources are older
than the existing file are skipped unless ``--force``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import (
    AOT_DATA_SHAPES,
    AOT_KINDS,
    AOT_SAMPLE_COUNTS,
    DEFAULT_PARAMS,
    artifact_name,
    example_args,
    gram_program,
)


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(kind: str, m: int, n: int, k: int) -> str:
    f = gram_program(kind)
    lowered = f.lower(*example_args(m, n, k))
    return to_hlo_text(lowered)


def build_all(out_dir: str, force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    src_mtime = max(
        os.path.getmtime(p)
        for p in [
            __file__,
            os.path.join(os.path.dirname(__file__), "model.py"),
            os.path.join(os.path.dirname(__file__), "kernels", "gram.py"),
        ]
    )
    n_built = 0
    for kind in AOT_KINDS:
        for m, n in AOT_DATA_SHAPES:
            for k in AOT_SAMPLE_COUNTS:
                name = artifact_name(kind, m, n, k)
                path = os.path.join(out_dir, f"{name}.hlo.txt")
                stale = (
                    force
                    or not os.path.exists(path)
                    or os.path.getmtime(path) < src_mtime
                )
                if stale:
                    text = lower_one(kind, m, n, k)
                    with open(path, "w") as fh:
                        fh.write(text)
                    n_built += 1
                    print(f"  lowered {name} ({len(text)} chars)")
                entries.append(
                    {
                        "name": name,
                        "file": f"{name}.hlo.txt",
                        "kind": kind,
                        "m": m,
                        "n": n,
                        "k": k,
                        "params": DEFAULT_PARAMS,
                        "dtype": "f32",
                        "inputs": [[m, n], [k, n]],
                        "output": [k, m],
                    }
                )
    manifest = {
        "version": 1,
        "jax_version": jax.__version__,
        "artifacts": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"artifacts: {n_built} lowered, {len(entries) - n_built} up to date")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(compat) ignored; use --out-dir")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out is not None:
        # Old Makefile compatibility: `--out ../artifacts/model.hlo.txt`.
        out_dir = os.path.dirname(args.out) or "."
    build_all(out_dir, force=args.force)


if __name__ == "__main__":
    sys.exit(main())
