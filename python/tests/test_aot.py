"""AOT pipeline: HLO-text emission, manifest integrity, incrementality."""

import json
import os

import numpy as np
import pytest

from compile.aot import build_all, lower_one, to_hlo_text
from compile.model import example_args, gram_program


def test_hlo_text_is_parseable_hlo_module():
    text = lower_one("rbf", 32, 8, 4)
    assert text.startswith("HloModule")
    # Entry layout must reflect the two inputs and tuple output the Rust
    # loader expects.
    assert "f32[32,8]" in text
    assert "f32[4,8]" in text
    assert "f32[4,32]" in text


def test_hlo_has_no_custom_calls():
    """interpret=True must lower pallas to plain HLO — a Mosaic
    custom-call would be unexecutable on the CPU PJRT client."""
    for kind in ("linear", "poly", "rbf"):
        text = lower_one(kind, 16, 4, 2)
        assert "custom-call" not in text, f"{kind} lowered to a custom-call"


def test_build_all_writes_manifest_and_is_incremental(tmp_path):
    out = str(tmp_path / "arts")
    # Shrink the sweep via monkeypatching for test speed.
    import compile.aot as aot_mod
    import compile.model as model_mod

    orig = (model_mod.AOT_DATA_SHAPES, model_mod.AOT_SAMPLE_COUNTS, model_mod.AOT_KINDS)
    try:
        for mod in (model_mod, aot_mod):
            mod.AOT_DATA_SHAPES = ((16, 4),)
            mod.AOT_SAMPLE_COUNTS = (2,)
            mod.AOT_KINDS = ("linear", "rbf")
        manifest = build_all(out)
        assert len(manifest["artifacts"]) == 2
        files = sorted(os.listdir(out))
        assert "manifest.json" in files
        for e in manifest["artifacts"]:
            path = os.path.join(out, e["file"])
            assert os.path.exists(path)
            assert e["inputs"] == [[e["m"], e["n"]], [e["k"], e["n"]]]
            assert e["output"] == [e["k"], e["m"]]
        # Second run rebuilds nothing (mtime-based).
        mtimes = {f: os.path.getmtime(os.path.join(out, f)) for f in files}
        build_all(out)
        for f in files:
            if f != "manifest.json":
                assert os.path.getmtime(os.path.join(out, f)) == mtimes[f]
    finally:
        model_mod.AOT_DATA_SHAPES, model_mod.AOT_SAMPLE_COUNTS, model_mod.AOT_KINDS = orig
        aot_mod.AOT_DATA_SHAPES, aot_mod.AOT_SAMPLE_COUNTS, aot_mod.AOT_KINDS = orig


def test_manifest_json_round_trips(tmp_path):
    import compile.aot as aot_mod
    import compile.model as model_mod

    orig = (model_mod.AOT_DATA_SHAPES, model_mod.AOT_SAMPLE_COUNTS, model_mod.AOT_KINDS)
    try:
        for mod in (model_mod, aot_mod):
            mod.AOT_DATA_SHAPES = ((8, 2),)
            mod.AOT_SAMPLE_COUNTS = (1,)
            mod.AOT_KINDS = ("linear",)
        out = str(tmp_path / "arts2")
        build_all(out)
        with open(os.path.join(out, "manifest.json")) as fh:
            m = json.load(fh)
        assert m["version"] == 1
        assert m["artifacts"][0]["name"] == "gram_linear_m8_n2_k1"
    finally:
        model_mod.AOT_DATA_SHAPES, model_mod.AOT_SAMPLE_COUNTS, model_mod.AOT_KINDS = orig
        aot_mod.AOT_DATA_SHAPES, aot_mod.AOT_SAMPLE_COUNTS, aot_mod.AOT_KINDS = orig


def test_lowered_program_executes_with_correct_numerics():
    """Compile the lowered module and compare against the oracle — the
    closest in-process proxy for what the Rust PJRT client executes (the
    true cross-language round-trip is covered by `cargo test` in
    rust/src/runtime)."""
    f = gram_program("rbf")
    lowered = f.lower(*example_args(32, 8, 4))
    compiled = lowered.compile()
    rng = np.random.default_rng(5)
    a = rng.normal(size=(32, 8)).astype(np.float32)
    s = a[:4].copy()
    (q,) = compiled(a, s)
    from compile.kernels.ref import gram_block_ref

    r = np.asarray(gram_block_ref(a, s, kind="rbf"))
    np.testing.assert_allclose(np.asarray(q), r, rtol=1e-5, atol=1e-5)


def test_hlo_text_entry_is_tupled():
    """The Rust loader unwraps a 1-tuple (`to_tuple1`); the emitted entry
    computation must therefore return a tuple."""
    text = lower_one("linear", 8, 2, 1)
    assert "->(f32[1,8]" in text.replace(" ", "")
