"""L1 correctness: the Pallas gram kernel vs the pure-jnp oracle.

This is the core build-time correctness signal — the Rust runtime trusts
the artifacts these kernels lower to. Hypothesis sweeps shapes, kernel
kinds, parameters, and tile sizes (including tiles that don't divide the
problem, exercising pallas' masked edges).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gram import gram_block
from compile.kernels.ref import gram_block_ref

KINDS = ("linear", "poly", "rbf")


def _rand(shape, seed, scale=1.0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, dtype=dtype)


def _tol(kind):
    # poly cubes values — relative error amplifies ~3x; f32 baseline.
    return dict(rtol=2e-4, atol=2e-4) if kind == "poly" else dict(rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("kind", KINDS)
def test_matches_ref_basic(kind):
    a = _rand((64, 16), 1)
    s = _rand((8, 16), 2)
    q = gram_block(a, s, kind=kind)
    r = gram_block_ref(a, s, kind=kind)
    np.testing.assert_allclose(np.asarray(q), np.asarray(r), **_tol(kind))


@pytest.mark.parametrize("kind", KINDS)
def test_single_sampled_row(kind):
    """k = 1 is the classical DCD shape (one kernel row per iteration)."""
    a = _rand((50, 7), 3)
    s = _rand((1, 7), 4)
    q = gram_block(a, s, kind=kind)
    assert q.shape == (1, 50)
    r = gram_block_ref(a, s, kind=kind)
    np.testing.assert_allclose(np.asarray(q), np.asarray(r), **_tol(kind))


def test_rbf_self_row_is_one():
    a = _rand((20, 5), 5)
    q = gram_block(a, a[3:4], kind="rbf", sigma=2.0)
    assert abs(float(q[0, 3]) - 1.0) < 1e-5  # f32 norm-expansion roundoff


def test_poly_params_change_result():
    a = _rand((10, 4), 6)
    s = _rand((2, 4), 7)
    q1 = gram_block(a, s, kind="poly", c=0.0, d=3)
    q2 = gram_block(a, s, kind="poly", c=1.0, d=2)
    assert not np.allclose(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(
        np.asarray(q2),
        np.asarray(gram_block_ref(a, s, kind="poly", c=1.0, d=2)),
        **_tol("poly"),
    )


def test_rejects_mismatched_features():
    a = _rand((10, 4), 8)
    s = _rand((2, 5), 9)
    with pytest.raises(ValueError, match="feature dims"):
        gram_block(a, s)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 200),
    n=st.integers(1, 48),
    k=st.integers(1, 40),
    kind=st.sampled_from(KINDS),
    seed=st.integers(0, 2**31),
)
def test_property_matches_ref(m, n, k, kind, seed):
    a = _rand((m, n), seed)
    s = _rand((k, n), seed + 1)
    q = gram_block(a, s, kind=kind, c=0.5, d=2, sigma=0.5)
    r = gram_block_ref(a, s, kind=kind, c=0.5, d=2, sigma=0.5)
    assert q.shape == (k, m)
    np.testing.assert_allclose(np.asarray(q), np.asarray(r), **_tol(kind))


@settings(max_examples=20, deadline=None)
@given(
    bk=st.integers(1, 16),
    bm=st.integers(1, 64),
    kind=st.sampled_from(KINDS),
)
def test_property_tile_sizes_do_not_change_result(bk, bm, kind):
    """Tiling is an implementation detail: any (bk, bm) gives the same Q,
    including tiles that don't divide (k, m)."""
    a = _rand((57, 11), 10)
    s = _rand((13, 11), 11)
    q = gram_block(a, s, kind=kind, bk=bk, bm=bm)
    r = gram_block_ref(a, s, kind=kind)
    np.testing.assert_allclose(np.asarray(q), np.asarray(r), **_tol(kind))


@pytest.mark.parametrize("kind", KINDS)
def test_large_scale_values_stay_finite(kind):
    """RBF with distant points must underflow to 0, not NaN; poly grows
    but stays finite for moderate inputs."""
    a = _rand((30, 8), 12, scale=10.0)
    s = _rand((4, 8), 13, scale=10.0)
    q = np.asarray(gram_block(a, s, kind=kind))
    assert np.isfinite(q).all()
    if kind == "rbf":
        assert (q >= 0.0).all() and (q <= 1.0 + 1e-6).all()


def test_jit_cache_reuses_compilation():
    """Repeated calls with the same static config must not retrace (guards
    the request-path no-Python property at the L2 boundary)."""
    a = _rand((32, 8), 14)
    s = _rand((4, 8), 15)
    f = jax.jit(lambda a, s: gram_block(a, s, kind="rbf"))
    q1 = f(a, s)
    q2 = f(a, s)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
