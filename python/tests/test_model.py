"""L2 correctness: the AOT-facing gram programs (shape contracts, tuple
convention, numerical agreement with the oracle at the lowered shapes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import gram_block_ref
from compile.model import (
    AOT_DATA_SHAPES,
    AOT_KINDS,
    AOT_SAMPLE_COUNTS,
    artifact_name,
    example_args,
    gram_apply,
    gram_program,
)


@pytest.mark.parametrize("kind", AOT_KINDS)
def test_program_returns_one_tuple(kind):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(32, 8)), dtype=jnp.float32)
    s = jnp.asarray(rng.normal(size=(4, 8)), dtype=jnp.float32)
    out = gram_program(kind)(a, s)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (4, 32)
    assert out[0].dtype == jnp.float32


@pytest.mark.parametrize("kind", AOT_KINDS)
def test_program_matches_ref_at_aot_shape(kind):
    """Exact agreement at the smallest lowered shape (the one the Rust
    runtime integration test replays)."""
    m, n = AOT_DATA_SHAPES[0]
    k = AOT_SAMPLE_COUNTS[1]  # 8
    rng = np.random.default_rng(1)
    # Modest scale so RBF values don't all underflow at n = 64 (which
    # would make the comparison vacuous).
    a = jnp.asarray(rng.normal(size=(m, n)) * 0.2, dtype=jnp.float32)
    s = jnp.asarray(a[rng.integers(0, m, size=k)])
    q = gram_apply(kind, a, s)
    r = gram_block_ref(a, s, kind=kind)
    tol = 5e-4 if kind == "poly" else 2e-5
    np.testing.assert_allclose(np.asarray(q), np.asarray(r), rtol=tol, atol=tol)
    assert float(np.abs(np.asarray(q)).max()) > 0.1, "comparison is vacuous"


def test_example_args_match_program_signature():
    for m, n in AOT_DATA_SHAPES:
        for k in AOT_SAMPLE_COUNTS:
            a_spec, s_spec = example_args(m, n, k)
            assert a_spec.shape == (m, n)
            assert s_spec.shape == (k, n)
            assert a_spec.dtype == jnp.float32


def test_artifact_names_are_unique_and_parseable():
    names = set()
    for kind in AOT_KINDS:
        for m, n in AOT_DATA_SHAPES:
            for k in AOT_SAMPLE_COUNTS:
                name = artifact_name(kind, m, n, k)
                assert name not in names
                names.add(name)
                assert name == f"gram_{kind}_m{m}_n{n}_k{k}"


def test_programs_lower_without_error():
    """Every (kind, shape) combination must lower to stablehlo — the
    minimal guarantee `make artifacts` relies on."""
    for kind in AOT_KINDS:
        f = gram_program(kind)
        lowered = f.lower(*example_args(*AOT_DATA_SHAPES[0], AOT_SAMPLE_COUNTS[0]))
        ir = str(lowered.compiler_ir("stablehlo"))
        assert "module" in ir
